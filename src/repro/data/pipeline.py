"""Deterministic synthetic token pipeline.

Production posture without external data: a counter-based PRNG stream
(threefry via numpy's Philox with a (step, host) key) generates token
batches. Determinism properties the tests assert:

  * step-addressable: batch(step) is a pure function of (seed, step) — a
    restarted job resumes mid-epoch with no state file;
  * host-sharded: each data-parallel host draws only its slice, and the
    union over hosts equals the single-host stream (elastic-safe);
  * next-token labels: labels are tokens shifted left, with the final
    position masked.

Structured sequences (a noisy order-k Markov chain) rather than uniform
noise, so cross-entropy measurably *decreases* during the smoke train run —
uniform tokens would make loss flat and hide training bugs.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticTokenDataset:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    markov_states: int = 64

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide over hosts")

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def _transition(self) -> np.ndarray:
        """Fixed sparse-ish Markov transition over a small state space."""
        rng = np.random.Generator(np.random.Philox(key=self.seed))
        k = self.markov_states
        t = rng.dirichlet(np.full(k, 0.1), size=k)
        return t

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """The (host-local) batch for a given step."""
        # counter-based: (seed, step, host) -> a 2-element Philox key
        rng = np.random.Generator(np.random.Philox(
            key=(self.seed * 1_000_003 + step, self.host_id)))
        b, s = self.host_batch, self.seq
        t = self._transition()
        k = self.markov_states
        states = np.empty((b, s + 1), np.int64)
        states[:, 0] = rng.integers(k, size=b)
        # vectorized chain: sample via inverse CDF per step
        cdf = np.cumsum(t, axis=1)
        u = rng.random((b, s))
        for i in range(s):
            states[:, i + 1] = (
                cdf[states[:, i]] < u[:, i:i + 1]).sum(axis=1)
        # map states to vocab ids with deterministic offsets + noise tokens
        base = (states * (self.vocab // k)) % self.vocab
        noise = rng.integers(self.vocab, size=(b, s + 1))
        use_noise = rng.random((b, s + 1)) < 0.05
        toks = np.where(use_noise, noise, base).astype(np.int32)
        tokens = toks[:, :-1]
        labels = toks[:, 1:].astype(np.int32)
        mask = np.ones((b, s), np.float32)
        return {"tokens": tokens, "labels": labels, "mask": mask}


def make_batch_iterator(dataset: SyntheticTokenDataset, start_step: int = 0):
    step = start_step
    while True:
        yield step, dataset.batch(step)
        step += 1
