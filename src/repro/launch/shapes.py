"""Assigned input shapes x architectures: the 40-cell matrix.

``train_*`` lowers ``train_step``; ``prefill_*`` lowers the parallel prefill;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len KV cache). ``long_500k`` is skipped for pure full-attention archs
(quadratic) per the assignment — the skip table lives here and is surfaced
in DESIGN.md / EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.models import build_model
from repro.models.config import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> str | None:
    """None if the (arch, shape) cell runs; else the documented skip."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention architecture: 500k decode is quadratic "
                "(see DESIGN.md §5)")
    return None


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in list_archs() for s in SHAPES]


def runnable_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, s in all_cells()
            if cell_skip_reason(get_arch(a), SHAPES[s]) is None]


# Per-(arch, shape) microbatch counts for gradient accumulation, sized so the
# per-chip activation footprint fits v5e HBM (validated by the dry-run's
# memory_analysis; see EXPERIMENTS.md §Dry-run).
MICROBATCHES: dict[tuple[str, str], int] = {
    ("deepseek-v2-236b", "train_4k"): 16,
    ("llama-3.2-vision-11b", "train_4k"): 8,
    ("codeqwen1.5-7b", "train_4k"): 8,
    ("rwkv6-7b", "train_4k"): 8,
    ("deepseek-moe-16b", "train_4k"): 4,
    ("hymba-1.5b", "train_4k"): 4,
    ("gemma2-2b", "train_4k"): 4,
    ("h2o-danube-1.8b", "train_4k"): 4,
    ("stablelm-1.6b", "train_4k"): 4,
    ("whisper-base", "train_4k"): 2,
}


def microbatches_for(arch: str, shape: str) -> int:
    return MICROBATCHES.get((arch, shape), 1)


def _token_dtype():
    return jnp.int32


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.
    No device allocation — suitable for .lower()."""
    B, S = shape.global_batch, shape.seq
    f = jnp.dtype(cfg.compute_dtype)
    i = _token_dtype()
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        batch = {"tokens": sds((B, S), i), "labels": sds((B, S), i)}
        if cfg.cross_attn_period:
            batch["img"] = sds((B, cfg.n_img_tokens, cfg.d_model), f)
        if cfg.enc_dec:
            batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), f)
        return {"batch": batch}

    model = build_model(cfg)
    if shape.kind == "prefill":
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
        out = {"tokens": sds((B, S), i), "cache": cache}
        if cfg.cross_attn_period:
            out["img"] = sds((B, cfg.n_img_tokens, cfg.d_model), f)
        if cfg.enc_dec:
            out["frames"] = sds((B, cfg.enc_seq, cfg.d_model), f)
        return out

    # decode: one new token against a cache of length seq
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {"tokens": sds((B, 1), i), "cache": cache}


def params_shape(cfg: ArchConfig):
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))
