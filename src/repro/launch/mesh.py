"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to materialize the placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the global batch."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
