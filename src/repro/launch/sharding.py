"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Scheme (DESIGN.md §6):
  * batch over ("pod", "data")  — data parallelism;
  * tensor parallelism over "model": attention heads, d_ff, vocab, and the
    MoE expert axis (expert parallelism -> all-to-all dispatch);
  * FSDP over "data" (and "pod" for >=30B params): the non-TP dim of every
    weight is sharded and gathered per-layer inside the scan;
  * KV caches: batch over dp; heads over "model" when divisible, else the
    sequence axis (flash-decoding-style partial reductions).

Every proposed axis is divisibility-checked against the actual dim and
dropped if it does not divide — that is what makes one rule table serve all
10 architectures (e.g. hymba's vocab 32001 silently falls back to
replicated-vocab embedding).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

from .mesh import dp_axes

# leaf name -> (role), resolved against the last two (or one) dims
_COL = {"wq", "wk", "wv", "w_in", "w_gate", "shared_in", "shared_gate",
        "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b", "w_r", "w_k", "w_v",
        "w_g", "cm_r", "cm_k", "decay_a", "decay_b"}
_ROW = {"wo", "w_out", "shared_out", "cm_v", "w_o"}
_VEC_MODEL = {"bq", "bk", "bv", "dt_bias", "D_skip", "bonus", "decay_base"}
_REPL = {"scale", "bias", "gate", "mu", "mu_c", "pos_embed", "dec_pos",
         "enc_pos"}
_MODEL_DIM2 = {"A_log", "w_bcdt"}      # (..., di, small): model on dim -2
_MODEL_LAST = {"conv_w"}               # (..., small, di): model on dim -1


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a] if a in mesh.axis_names else 1
    return out


def _fit(mesh, dim: int, axes):
    """axes if they divide dim and exist in the mesh, else None."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    if dim % _axis_size(mesh, axes):
        return None
    return axes if len(axes) > 1 else axes[0]


def _spec(mesh, shape, assignment: dict[int, Any]) -> P:
    """assignment: dim index -> proposed axes (checked + fallback None)."""
    entries = []
    for i, d in enumerate(shape):
        ax = assignment.get(i)
        ax = _fit(mesh, d, ax) if ax is not None else None
        entries.append(ax)
    return P(*entries)


def fsdp_axes(mesh, cfg: ArchConfig | None = None):
    if cfg is not None and cfg.n_params() >= 30_000_000_000 \
            and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


# Serving: if the TP-only (model-axis) shard of the weights fits comfortably
# in HBM, drop the FSDP dim — per-token weight all-gathers dominate decode
# otherwise (EXPERIMENTS.md §Perf, codeqwen decode cell).
SERVE_TP_BUDGET_BYTES = 6e9


def serve_tp_only(mesh, cfg: ArchConfig | None) -> bool:
    if cfg is None or "model" not in mesh.axis_names:
        return False
    bytes_per_param = 2 if cfg.param_dtype == "bfloat16" else 4
    per_chip = cfg.n_params() * bytes_per_param / mesh.shape["model"]
    return per_chip <= SERVE_TP_BUDGET_BYTES


def _param_spec(path_names: tuple[str, ...], leaf, mesh,
                cfg: ArchConfig | None, serve: bool = False) -> P:
    name = path_names[-1]
    shape = leaf.shape
    nd = len(shape)
    fsdp = None if (serve and serve_tp_only(mesh, cfg)) \
        else fsdp_axes(mesh, cfg)
    in_moe = "moe" in path_names
    if name == "embed":
        if cfg is not None and cfg.tie_embeddings:
            # vocab-sharded so the (transposed) LM head keeps logits
            # sharded over "model"; the lookup pays a reshard.
            return _spec(mesh, shape, {0: "model", 1: fsdp})
        # untied: shard d_model only -> communication-free gather; the
        # separate head carries the vocab sharding.
        return _spec(mesh, shape, {1: ("data", "model")})
    if name == "head":
        return _spec(mesh, shape, {0: fsdp, 1: "model"})
    if name == "router":
        return _spec(mesh, shape, {nd - 2: fsdp})
    if in_moe and name in ("w_in", "w_gate"):
        # (L, E, D, Fe): expert parallelism on E, FSDP on D
        return _spec(mesh, shape, {nd - 3: "model", nd - 2: fsdp})
    if in_moe and name == "w_out":
        # (L, E, Fe, D)
        return _spec(mesh, shape, {nd - 3: "model", nd - 1: fsdp})
    if name in _COL:
        if nd == 1:
            return _spec(mesh, shape, {0: "model"})
        return _spec(mesh, shape, {nd - 2: fsdp, nd - 1: "model"})
    if name in _ROW:
        return _spec(mesh, shape, {nd - 2: "model", nd - 1: fsdp})
    if name in _VEC_MODEL:
        return _spec(mesh, shape, {nd - 1: "model"})
    if name in _MODEL_DIM2:
        return _spec(mesh, shape, {nd - 2: "model"})
    if name in _MODEL_LAST:
        return _spec(mesh, shape, {nd - 1: "model"})
    if name == "scale" and "ln_x" in path_names:
        return _spec(mesh, shape, {nd - 1: "model"})
    # default: replicated (norm scales, gates, mixing vectors, counts)
    return P(*([None] * nd))


def _path_names(path) -> tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:  # pragma: no cover
            names.append(str(k))
    return tuple(names)


def param_pspecs(params_shape, mesh, cfg: ArchConfig | None = None,
                 serve: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(_path_names(path), leaf, mesh, cfg,
                                       serve),
        params_shape)


def opt_pspecs(opt_shape, mesh, cfg: ArchConfig | None = None):
    """m/v mirror the parameter specs; count replicated."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: (
            P() if _path_names(path)[-1] == "count"
            else _param_spec(_path_names(path)[1:], leaf, mesh, cfg)),
        opt_shape)


def state_pspecs(state_shape, mesh, cfg: ArchConfig | None = None):
    return {
        "params": param_pspecs(state_shape["params"], mesh, cfg),
        "opt": opt_pspecs(state_shape["opt"], mesh, cfg),
        "step": P(),
    }


def batch_pspecs(batch_shape, mesh):
    dp = dp_axes(mesh)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        return _spec(mesh, leaf.shape, {0: dp})

    return jax.tree.map(spec, batch_shape)


def _cache_spec(path_names, leaf, mesh) -> P:
    name = path_names[-1]
    shape = leaf.shape
    nd = len(shape)
    dp = dp_axes(mesh)
    if nd == 0:
        return P()
    if name in ("k", "v"):
        # (L, B, H, S, D) (kv / cross_kv stacks)
        assign = {1: dp}
        if _fit(mesh, shape[2], "model"):
            assign[2] = "model"
        else:
            assign[3] = "model" if _fit(mesh, shape[1], dp) else \
                ("data", "model")
        return _spec(mesh, shape, assign)
    if name in ("c_kv", "k_pe"):
        # (L, B, S, lat)
        assign = {1: dp, 2: "model"}
        if not _fit(mesh, shape[1], dp):
            assign = {2: ("data", "model")}
        return _spec(mesh, shape, assign)
    if name == "ssm":
        return _spec(mesh, shape, {1: dp, 2: "model"})
    if name == "conv":
        return _spec(mesh, shape, {1: dp, 3: "model"})
    if name == "wkv":
        return _spec(mesh, shape, {1: dp, 2: "model"})
    if name in ("prev_t", "prev_c"):
        return _spec(mesh, shape, {1: dp, 2: "model"})
    return P(*([None] * nd))


def cache_pspecs(cache_shape, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_spec(_path_names(path), leaf, mesh),
        cache_shape)


def logits_pspec(mesh, batch: int | None = None,
                 vocab: int | None = None):
    if batch is None or vocab is None:
        return P(dp_axes(mesh), None, "model")
    return _spec(mesh, (batch, 1, vocab), {0: dp_axes(mesh), 2: "model"})


def to_named(tree_of_pspecs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def validate_specs(shapes_tree, specs_tree, mesh) -> list[str]:
    """Sanity: every sharded dim divisible. Returns list of violations."""
    errors = []

    def check(path, leaf, spec):
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            size = _axis_size(mesh, ax)
            if leaf.shape[i] % size:
                errors.append(
                    f"{'/'.join(_path_names(path))}: dim {i} "
                    f"({leaf.shape[i]}) not divisible by {ax} ({size})")

    jax.tree_util.tree_map_with_path(check, shapes_tree, specs_tree)
    return errors
