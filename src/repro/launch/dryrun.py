import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ^ MUST precede any jax import: jax locks the device count on first init.
# Everything below is ordinary imports.

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) cell on the production meshes and record the
memory / cost / collective analysis that feeds EXPERIMENTS.md §Dry-run and
§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_arch, list_archs                    # noqa: E402
from repro.launch import sharding as sh                           # noqa: E402
from repro.launch.mesh import make_production_mesh                # noqa: E402
from repro.launch.shapes import (SHAPES, cell_skip_reason,        # noqa: E402
                                 input_specs, microbatches_for)
from repro.models import build_model                              # noqa: E402
from repro.optim import AdamW, cosine_schedule                    # noqa: E402
from repro.roofline import roofline_report                        # noqa: E402
from repro.roofline.hlo_parse import hlo_cost_analysis            # noqa: E402
from repro.train import init_train_state, make_train_step        # noqa: E402

DEFAULT_OUT = Path("experiments/dryrun")


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh, verbose: bool = True):
    """Build + lower + compile one cell. Returns the analysis record."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": skip}
    model = build_model(cfg, remat=(shape.kind == "train"))
    specs = input_specs(cfg, shape)
    t0 = time.perf_counter()

    if shape.kind == "train":
        optimizer = AdamW(lr=cosine_schedule(3e-4, 100, 10_000))
        state_shape = jax.eval_shape(
            lambda rng: init_train_state(model, optimizer, rng),
            jax.random.PRNGKey(0))
        state_specs = sh.state_pspecs(state_shape, mesh, cfg)
        batch_specs = sh.batch_pspecs(specs["batch"], mesh)
        metrics_shape = jax.eval_shape(
            lambda s, b: make_train_step(model, optimizer,
                                         microbatches_for(arch, shape_name)
                                         )(s, b)[1],
            state_shape, specs["batch"])
        metrics_specs = jax.tree.map(lambda _: P(), metrics_shape)
        step = make_train_step(model, optimizer,
                               microbatches_for(arch, shape_name))
        jitted = jax.jit(step,
                         in_shardings=(_named(state_specs, mesh),
                                       _named(batch_specs, mesh)),
                         out_shardings=(_named(state_specs, mesh),
                                        _named(metrics_specs, mesh)),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_shape, specs["batch"])
    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspec = sh.param_pspecs(params_shape, mesh, cfg, serve=True)
        cspec = sh.cache_pspecs(specs["cache"], mesh)
        tspec = sh.batch_pspecs({"t": specs["tokens"]}, mesh)["t"]
        extra_keys = [k for k in ("img", "frames") if k in specs]
        extras = {k: specs[k] for k in extra_keys}
        espec = sh.batch_pspecs(extras, mesh)

        def prefill_step(params, tokens, cache, extras):
            if cfg.enc_dec:
                return model.prefill(params, tokens, cache,
                                     extras["frames"])
            if cfg.cross_attn_period:
                return model.prefill(params, tokens, cache, extras["img"])
            return model.prefill(params, tokens, cache)

        jitted = jax.jit(
            prefill_step,
            in_shardings=(_named(pspec, mesh), _named(tspec, mesh),
                          _named(cspec, mesh), _named(espec, mesh)),
            out_shardings=(_named(sh.logits_pspec(
                mesh, shape.global_batch, cfg.padded_vocab), mesh),
                           _named(cspec, mesh)),
            donate_argnums=(2,))
        lowered = jitted.lower(params_shape, specs["tokens"],
                               specs["cache"], extras)
    else:  # decode
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspec = sh.param_pspecs(params_shape, mesh, cfg, serve=True)
        cspec = sh.cache_pspecs(specs["cache"], mesh)
        tspec = sh.batch_pspecs({"t": specs["tokens"]}, mesh)["t"]

        def serve_step(params, cache, tokens):
            return model.decode_step(params, cache, tokens)

        jitted = jax.jit(
            serve_step,
            in_shardings=(_named(pspec, mesh), _named(cspec, mesh),
                          _named(tspec, mesh)),
            out_shardings=(_named(sh.logits_pspec(
                mesh, shape.global_batch, cfg.padded_vocab), mesh),
                           _named(cspec, mesh)),
            donate_argnums=(1,))
        lowered = jitted.lower(params_shape, specs["cache"],
                               specs["tokens"])

    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception:  # pragma: no cover
        mem_info = {}

    hlo = compiled.as_text()
    # Trip-count-aware walk: XLA's cost_analysis counts while bodies once,
    # under-reporting scan-over-layers programs by ~L x (see roofline/).
    walk = hlo_cost_analysis(hlo)
    coll = walk["collectives"]
    import math
    chips = int(math.prod(mesh.shape.values()))

    flops = float(walk["flops"])
    byts = float(walk["bytes"])
    roof = roofline_report(
        flops_per_chip=flops, bytes_per_chip=byts,
        collective_per_chip=coll, chips=chips, cfg=cfg, kind=shape.kind,
        global_batch=shape.global_batch, seq=shape.seq,
        dtype=cfg.compute_dtype)

    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": dict(mesh.shape), "chips": chips,
        "kind": shape.kind,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "lower_s": t_lower, "compile_s": t_compile,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "hlo_walk": {"flops": walk["flops"], "bytes_fused": walk["bytes"],
                     "bytes_upper": walk["bytes_upper"]},
        "memory_analysis": mem_info,
        "collective_bytes": coll,
        "roofline": roof,
        "hlo_lines": hlo.count("\n"),
    }
    if verbose:
        ms = mem_info.get("temp_bytes")
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"flops/chip={flops:.3e} bytes/chip={byts:.3e} "
              f"coll/chip={coll['total']:.3e} "
              f"temp={ms/2**30 if ms else float('nan'):.2f}GiB "
              f"dominant={roof['dominant']} "
              f"roofline={roof['roofline_fraction']:.3f}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_tag = "multipod" if args.multi_pod else "singlepod"
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = ([(args.arch, args.shape)] if args.arch and args.shape
             else [(a, s) for a in (list_archs() if args.all or not args.arch
                                    else [args.arch])
                   for s in (list(SHAPES) if args.all or not args.shape
                             else [args.shape])])
    failures = 0
    for arch, shape in cells:
        print(f"[dryrun] {arch} x {shape} on {mesh_tag} "
              f"{tuple(mesh.shape.values())}")
        try:
            with mesh:
                rec = lower_cell(arch, shape, mesh)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        rec["mesh_tag"] = mesh_tag
        path = out_dir / f"{arch}--{shape}--{mesh_tag}.json"
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
        if rec["status"] == "skipped":
            print(f"  SKIP: {rec['reason']}")
    print(f"[dryrun] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
