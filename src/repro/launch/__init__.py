"""Launcher: production mesh, per-(arch, shape) input specs, sharding rules,
multi-pod dry-run, train/serve drivers."""
