"""Cross-pod synchronization with int8 compression + error feedback.

Within a pod, XLA SPMD owns the (fast-ICI) gradient all-reduce. *Across*
pods — the slow axis at 1000+ node scale — this module implements
local-SGD-style synchronization (DiLoCo-flavored): each pod runs H inner
steps independently, then pods exchange the parameter *delta* since the last
sync, int8-quantized with an error-feedback residual so the compression is
unbiased over time. Bandwidth per sync drops 4x (f32) / 2x (bf16) plus the
1/H amortization.

On this single-host container pods are simulated as independent replicas
(separate param copies); the same arithmetic drives a real multi-pod
deployment where `exchange` is a psum over the pod axis.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.optim.compression import compress_int8, decompress_int8


@dataclass
class CrossPodSync:
    n_pods: int
    inner_steps: int = 8           # H: steps between syncs
    outer_lr: float = 1.0          # SGD on the averaged delta

    residuals: list = field(default_factory=list)  # error feedback per pod

    def init(self, params) -> list:
        """Per-pod replica states + residuals."""
        self.residuals = [
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            for _ in range(self.n_pods)]
        return [params for _ in range(self.n_pods)]

    def should_sync(self, step: int) -> bool:
        return step > 0 and step % self.inner_steps == 0

    def sync(self, anchor, pod_params: list) -> tuple:
        """anchor: params at the last sync; pod_params: per-pod current.
        Returns (new_anchor, new per-pod params, stats)."""
        n = self.n_pods
        flat_anchor, treedef = jax.tree_util.tree_flatten(anchor)
        deltas_q = []
        bytes_raw = bytes_sent = 0
        for pi in range(n):
            flat_p = treedef.flatten_up_to(pod_params[pi])
            flat_r = treedef.flatten_up_to(self.residuals[pi])
            qs = []
            new_r = []
            for a, p, r in zip(flat_anchor, flat_p, flat_r):
                delta = p.astype(jnp.float32) - a.astype(jnp.float32)
                q, scale, err = compress_int8(delta, r)
                qs.append((q, scale))
                new_r.append(err)
                bytes_raw += delta.size * 4
                bytes_sent += q.size * 1 + 4
            self.residuals[pi] = jax.tree_util.tree_unflatten(treedef, new_r)
            deltas_q.append(qs)
        # all-reduce (mean) of the decompressed deltas across pods
        mean_delta = []
        for li, a in enumerate(flat_anchor):
            acc = jnp.zeros(a.shape, jnp.float32)
            for pi in range(n):
                q, scale = deltas_q[pi][li]
                acc = acc + decompress_int8(q, scale)
            mean_delta.append(acc / n)
        new_anchor_flat = [
            (a.astype(jnp.float32) + self.outer_lr * d).astype(a.dtype)
            for a, d in zip(flat_anchor, mean_delta)]
        new_anchor = jax.tree_util.tree_unflatten(treedef, new_anchor_flat)
        new_pods = [new_anchor for _ in range(n)]
        stats = {"bytes_raw": bytes_raw, "bytes_sent": bytes_sent,
                 "compression": bytes_raw / max(bytes_sent, 1)}
        return new_anchor, new_pods, stats
