"""Fault-tolerant training driver: checkpoint/restart + watchdog + elastic
resume.

The driver owns the outer loop: deterministic data by step number, periodic
atomic checkpoints, straggler accounting, and crash recovery — ``run`` can
be killed at any step and re-invoked; it resumes from the latest checkpoint
bit-exactly (tested). A ``fault_injector`` hook lets tests kill the loop at
a chosen step.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticTokenDataset

from .watchdog import StepWatchdog


class InjectedFault(RuntimeError):
    pass


@dataclass
class TrainDriver:
    model: object
    optimizer: object
    train_step: Callable           # jitted (state, batch) -> (state, metrics)
    dataset: SyntheticTokenDataset
    ckpt: CheckpointManager
    total_steps: int
    watchdog: StepWatchdog = field(default_factory=StepWatchdog)
    fault_injector: Callable[[int], None] | None = None
    log_every: int = 10

    def init_or_restore(self, rng, shardings=None):
        """Fresh state, or the latest checkpoint if one exists."""
        from repro.train import init_train_state
        start = self.ckpt.latest_step()
        if start is None:
            state = init_train_state(self.model, self.optimizer, rng)
            return state, 0
        like = jax.eval_shape(
            lambda r: init_train_state(self.model, self.optimizer, r), rng)
        state, manifest = self.ckpt.restore_latest(like, shardings)
        return state, int(manifest["step"])

    def run(self, rng, shardings=None) -> dict:
        state, start = self.init_or_restore(rng, shardings)
        history = []
        for step in range(start, self.total_steps):
            if self.fault_injector is not None:
                self.fault_injector(step)   # may raise InjectedFault
            batch = self.dataset.batch(step)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            report = self.watchdog.record(step, dt)
            history.append({"step": step, "loss": loss, "s": dt,
                            "straggle": bool(report)})
            if self.ckpt.should_save(step + 1):
                self.ckpt.save(step + 1, state,
                               {"loss": loss})
            if step % self.log_every == 0:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"dt={dt*1e3:.0f}ms")
        final = self.ckpt.save(self.total_steps, state, {"final": True})
        return {"state": state, "history": history,
                "final_checkpoint": str(final),
                "stragglers": [r.__dict__ for r in self.watchdog.reports],
                "suspects": self.watchdog.suspects()}
