from .watchdog import StepWatchdog, StragglerReport
from .driver import TrainDriver
from .crosspod import CrossPodSync

__all__ = ["StepWatchdog", "StragglerReport", "TrainDriver", "CrossPodSync"]
