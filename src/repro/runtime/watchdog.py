"""Straggler / hang detection.

At thousand-node scale, slow hosts dominate step time. The watchdog keeps a
rolling window of step durations (optionally per worker), flags steps beyond
a deadline of ``p50 x tolerance`` as straggles, flags workers whose straggle
*rate* exceeds a threshold as suspect (candidates for backup-worker
replacement), and declares a hang when a step exceeds ``hang_factor x p50``
— the restart driver then recovers from the last checkpoint.

Pure bookkeeping (injected clocks in tests), so the policy is unit-testable
without real failures.
"""

from __future__ import annotations

import statistics
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field


@dataclass
class StragglerReport:
    step: int
    worker: int
    duration_s: float
    deadline_s: float
    kind: str            # "straggle" | "hang"


@dataclass
class StepWatchdog:
    window: int = 50
    tolerance: float = 1.5       # straggle if > p50 * tolerance
    hang_factor: float = 10.0    # hang if > p50 * hang_factor
    suspect_rate: float = 0.3    # worker suspect if >30% recent straggles
    min_samples: int = 5

    _durations: deque = field(default_factory=lambda: deque(maxlen=200))
    _per_worker: dict = field(
        default_factory=lambda: defaultdict(lambda: deque(maxlen=50)))
    reports: list = field(default_factory=list)

    def p50(self) -> float | None:
        if len(self._durations) < self.min_samples:
            return None
        return statistics.median(self._durations)

    def deadline(self) -> float | None:
        p = self.p50()
        return None if p is None else p * self.tolerance

    def record(self, step: int, duration_s: float,
               worker: int = 0) -> StragglerReport | None:
        """Record a completed step; returns a report if it straggled."""
        p = self.p50()
        self._durations.append(duration_s)
        report = None
        if p is not None:
            if duration_s > p * self.hang_factor:
                report = StragglerReport(step, worker, duration_s,
                                         p * self.hang_factor, "hang")
            elif duration_s > p * self.tolerance:
                report = StragglerReport(step, worker, duration_s,
                                         p * self.tolerance, "straggle")
        self._per_worker[worker].append(1 if report else 0)
        if report:
            self.reports.append(report)
        return report

    def suspects(self) -> list[int]:
        """Workers whose recent straggle rate exceeds the threshold."""
        out = []
        for w, hist in self._per_worker.items():
            if len(hist) >= self.min_samples \
                    and sum(hist) / len(hist) > self.suspect_rate:
                out.append(w)
        return sorted(out)

    def is_hang(self, elapsed_s: float) -> bool:
        """Live check for an in-flight step (call while waiting)."""
        p = self.p50()
        return p is not None and elapsed_s > p * self.hang_factor
