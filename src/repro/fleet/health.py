"""Fleet-wide health: metric snapshots on the control bus.

Every process that has observability enabled (``repro.obs``) holds a
local :class:`~repro.obs.metrics.MetricsRegistry`. This module moves
those registries' snapshots through the same control bus the
orchestrator already uses — one more channel (``metrics``) in the
reserved ``fleet--`` namespace — so any host can assemble a fleet-wide
view without a second telemetry system:

* workers/serving hosts call :func:`publish_metrics` (or hook a
  :class:`MetricsPublisher` into their loop) to put their snapshot on
  the bus under their worker id;
* the coordinator (or an operator shell) calls
  :func:`aggregate_fleet_metrics` to merge every published snapshot —
  counters sum, gauges max, histogram buckets sum — and
  :func:`fleet_health` to render the wisdom-health report over it.

Snapshots are plain JSON documents, so the directory transport stores
them as ordinary ``fleet--metrics--<worker>`` files an operator can cat.
"""

from __future__ import annotations

from repro.obs import runtime as obs
from repro.obs.metrics import MetricsRegistry, merge_snapshots
from repro.obs.report import render_report

from .bus import ControlBus

#: Control-bus channel metric snapshots ride on (see ``bus.CHANNELS``).
METRICS_CHANNEL = "metrics"


def publish_metrics(bus: ControlBus, worker_id: str,
                    registry: MetricsRegistry | None = None) -> dict:
    """Publish one process's metric snapshot under its worker id.

    Uses the process-wide enabled registry when none is passed; raises
    ``RuntimeError`` when observability is disabled and there is nothing
    to snapshot (callers who may run disabled should guard on
    :func:`repro.obs.enabled` or use :class:`MetricsPublisher`, whose
    tick is a no-op in that case). Returns the published snapshot.

    Example::

        enable()
        ...                                   # serve / tune / work
        publish_metrics(bus, "host-1")
    """
    reg = registry if registry is not None else obs.metrics()
    if reg is None:
        raise RuntimeError(
            "observability is disabled and no registry was given; "
            "call repro.obs.enable() or pass registry=")
    snap = reg.snapshot()
    bus.publish(METRICS_CHANNEL, worker_id, snap)
    return snap


def fleet_snapshots(bus: ControlBus) -> dict[str, dict]:
    """Every published metric snapshot, keyed by worker id (sorted).

    The raw per-host view behind :func:`aggregate_fleet_metrics` —
    useful when a report should single out one host instead of merging.

    Example::

        for worker, snap in fleet_snapshots(bus).items():
            print(worker, snap["counters"].get("launch.count", 0))
    """
    out: dict[str, dict] = {}
    for name in bus.names(METRICS_CHANNEL):
        doc = bus.fetch(METRICS_CHANNEL, name)
        if doc is not None:
            out[name] = doc
    return out


def aggregate_fleet_metrics(bus: ControlBus) -> dict:
    """Merge every published snapshot into one fleet-wide snapshot.

    Counters and histogram buckets sum across hosts, gauges keep the
    max (see :func:`repro.obs.merge_snapshots`); the result has the
    same shape as a single-process snapshot, so every report and tool
    that reads snapshots works on it unchanged.

    Example::

        snap = aggregate_fleet_metrics(bus)
        save_snapshot(snap, "fleet-metrics.json")
    """
    return merge_snapshots(list(fleet_snapshots(bus).values()))


def fleet_health(bus: ControlBus, top: int = 10) -> str:
    """Render the wisdom-health report over the whole fleet's metrics.

    Deterministic text (a pure function of the published snapshots):
    per-scenario hit rates, tier breakdown, transfer-confidence
    distribution, and the top missing scenarios across every host that
    published — the coordinator's one-call answer to "how healthy is
    the fleet's wisdom right now?".

    Example::

        print(fleet_health(bus))
    """
    return render_report(aggregate_fleet_metrics(bus), top=top)


class MetricsPublisher:
    """Loop hook that republishes this process's snapshot every
    ``interval`` ticks (first tick included, so a short-lived worker
    still shows up on the bus). ``tick()`` is cheap and safe to call
    from serving or tuning loops: when observability is disabled it
    does nothing.

    Example::

        pub = MetricsPublisher(bus, "host-1", interval=256)
        while serving:
            step()
            pub.tick()
    """

    def __init__(self, bus: ControlBus, worker_id: str,
                 interval: int = 64,
                 registry: MetricsRegistry | None = None):
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval}")
        self.bus = bus
        self.worker_id = worker_id
        self.interval = interval
        self.registry = registry
        self.publishes = 0
        self._ticks = 0

    def tick(self) -> bool:
        """Publish when due; returns True if a publish happened."""
        due = self._ticks % self.interval == 0
        self._ticks += 1
        reg = self.registry if self.registry is not None else obs.metrics()
        if not due or reg is None:
            return False
        self.bus.publish(METRICS_CHANNEL, self.worker_id, reg.snapshot())
        self.publishes += 1
        return True
