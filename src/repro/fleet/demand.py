"""Fleet demand: aggregate per-worker wisdom misses, rank what to tune.

Workers publish their :class:`~repro.online.ScenarioTracker` snapshots
(canonical string keys, so the records survive JSON transport without
tuple/list drift) on the ``demand`` channel. The coordinator merges them
into one fleet-wide table and ranks scenarios by

    priority = misses x predicted_speedup

where ``predicted_speedup`` is a cheap cost-model probe: the score of the
config the fleet would select *today* (through the §4.5 heuristic against
current fleet wisdom) divided by the best of a few seeded random probes.
A scenario nobody misses never gets tuned; a heavily-missed scenario the
cost model thinks is already near-optimal ranks below a moderately-missed
one with 3x headroom.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from repro.core.device import get_device
from repro.core.registry import get_kernel
from repro.core.wisdom import Wisdom
from repro.distrib.sync import Transport, transport_wisdom
from repro.online.tracker import (ScenarioKey, ScenarioTracker, format_key,
                                  parse_key)
from repro.tuner.runner import CostModelEvaluator

from .bus import ControlBus

#: Probes drawn per scenario for the speedup estimate. Small on purpose:
#: this runs in the coordinator's planning loop over every hot scenario.
SPEEDUP_PROBES = 16


@dataclass
class DemandEntry:
    """Fleet-wide demand for one (kernel, scenario).

    The sum of every worker's wisdom-miss counters for the scenario,
    plus how many workers reported it — the raw material
    :func:`prioritize` ranks. Produced by :func:`aggregate_demand`.

    Example::

        entries = aggregate_demand(bus)
        hot = [e for e in entries if e.misses >= MIN_MISSES]
    """

    kernel: str
    key: ScenarioKey
    misses: int = 0
    launches: int = 0
    workers: int = 0          # how many workers reported it

    @property
    def key_str(self) -> str:
        return format_key(self.key)


@dataclass
class ScenarioPriority:
    """A demand entry with its cost-model headroom estimate attached.

    ``priority = misses x speedup``: traffic volume times how much the
    cost model thinks tuning could still win over what selection
    returns today. Produced by :func:`predicted_speedup` /
    :func:`prioritize`; consumed by the coordinator's planner.

    Example::

        for p in prioritize(entries, transport):
            print(p.entry.kernel, p.speedup, p.priority)
    """

    entry: DemandEntry
    current_score_us: float
    probe_score_us: float
    speedup: float            # current / best-probe (>= 1.0 when feasible)

    @property
    def priority(self) -> float:
        return self.entry.misses * self.speedup


def publish_demand(bus: ControlBus, worker_id: str,
                   trackers: dict[str, ScenarioTracker]) -> None:
    """Publish one worker's demand snapshot ({kernel_name: tracker}).

    Cumulative-counter semantics: each publish *replaces* the worker's
    previous snapshot (tracker counters only grow), so re-publishing is
    idempotent and the aggregate never double-counts a launch.

    Example::

        publish_demand(bus, "host-1", {"matmul": kernel.tracker})
    """
    bus.publish("demand", worker_id, {
        "worker": worker_id,
        "kernels": {name: tracker.snapshot()
                    for name, tracker in sorted(trackers.items())},
    })


def seed_demand(bus: ControlBus, worker_id: str,
                entries: list[tuple[str, ScenarioKey, int]]) -> None:
    """Publish a synthetic demand snapshot — (kernel, key, misses) triples.

    Test/benchmark/CLI convenience standing in for real trackers: builds
    throwaway ``ScenarioTracker``s with the requested miss counts and
    publishes them like a real worker would.

    Example::

        seed_demand(bus, "seed",
                    [("matmul", ("tpu-v5e", (256, 256, 256), "float32"), 5)])
    """
    trackers: dict[str, ScenarioTracker] = {}
    for kernel, key, misses in entries:
        t = trackers.setdefault(kernel, ScenarioTracker())
        t.observe(*key, tier="default", weight=misses)
    publish_demand(bus, worker_id, trackers)


def publish_latency(bus: ControlBus, worker_id: str,
                    observations: dict[str, dict[str, float]]) -> None:
    """Publish one serving host's observed per-scenario latencies.

    ``observations`` maps kernel name -> {canonical scenario key ->
    best observed latency in us}. Replace-style like demand snapshots
    (re-publishing is idempotent); the coordinator compares these against
    the ``predicted_us`` of transferred wisdom records and enqueues
    verification tuning for scenarios whose predictions regressed
    (see ``Coordinator.check_transfers``).

    Example::

        publish_latency(bus, "host-1",
                        {"matmul": {format_key(key): 512.3}})
    """
    bus.publish("latency", worker_id, {
        "worker": worker_id,
        "kernels": {k: {key: float(us) for key, us in sorted(v.items())}
                    for k, v in sorted(observations.items())},
    })


def aggregate_latency(bus: ControlBus) -> dict[tuple[str, str], float]:
    """Fleet-wide best observed latency per (kernel, scenario key).

    The *minimum* over workers: latency observations verify a transferred
    record's optimistic prediction, and the best-case observation is the
    fairest comparison (stragglers and noisy hosts must not trigger
    spurious verification jobs).

    Example::

        observed = aggregate_latency(bus)
        us = observed.get(("matmul", format_key(key)))
    """
    table: dict[tuple[str, str], float] = {}
    for doc in bus.docs("latency"):
        for kernel, scenarios in doc.get("kernels", {}).items():
            for key, us in scenarios.items():
                k = (kernel, key)
                us = float(us)
                if k not in table or us < table[k]:
                    table[k] = us
    return table


def aggregate_demand(bus: ControlBus) -> list[DemandEntry]:
    """Merge every worker's snapshot into one fleet-wide demand table.

    Sums misses/launches per (kernel, scenario) across all published
    snapshots and counts the reporting workers; deterministically
    ordered by (kernel, key) so every coordinator sees the same table.

    Example::

        entries = aggregate_demand(ControlBus(transport))
    """
    table: dict[tuple[str, str], DemandEntry] = {}
    for doc in bus.docs("demand"):
        for kernel, stats in doc.get("kernels", {}).items():
            for s in stats:
                k = (kernel, s["key"])
                entry = table.get(k)
                if entry is None:
                    entry = table[k] = DemandEntry(kernel,
                                                   parse_key(s["key"]))
                entry.misses += int(s.get("misses", 0))
                entry.launches += int(s.get("launches", 0))
                entry.workers += 1
    return [table[k] for k in sorted(table)]


def _probe_rng(kernel: str, key: ScenarioKey, seed: int
               ) -> np.random.Generator:
    h = hashlib.sha256(f"{seed}|{kernel}|{format_key(key)}".encode())
    return np.random.default_rng(int.from_bytes(h.digest()[:8], "little"))


def predicted_speedup(kernel: str, key: ScenarioKey, wisdom: Wisdom,
                      n_probes: int = SPEEDUP_PROBES,
                      seed: int = 0) -> ScenarioPriority | None:
    """Estimate tuning headroom for one scenario under the cost model.

    Returns None when the kernel is unknown on this host (a worker
    elsewhere may still tune it; the coordinator just cannot rank it).

    Example::

        pri = predicted_speedup("matmul",
                                ("tpu-v5e", (256, 256, 256), "float32"),
                                store.load("matmul"))
        if pri is not None and pri.speedup > 1.5: ...
    """
    try:
        builder = get_kernel(kernel)
    except KeyError:
        return None
    device_kind, problem, dtype = key
    evaluator = CostModelEvaluator(builder, problem, dtype,
                                   get_device(device_kind), verify="none")
    current, _tier = wisdom.select(device_kind, problem, dtype,
                                   builder.default_config())
    cur = evaluator(current).score_us
    rng = _probe_rng(kernel, key, seed)
    best = cur
    for cfg in builder.space.sample(rng, n_probes):
        best = min(best, evaluator(cfg).score_us)
    if not np.isfinite(best):
        # nothing feasible at all — no measurable headroom
        return ScenarioPriority(DemandEntry(kernel, key), cur, best, 1.0)
    speedup = (cur / best) if np.isfinite(cur) else float(n_probes)
    return ScenarioPriority(DemandEntry(kernel, key), cur, best,
                            max(speedup, 1.0))


def prioritize(entries: list[DemandEntry], transport: Transport,
               n_probes: int = SPEEDUP_PROBES,
               seed: int = 0) -> list[ScenarioPriority]:
    """Rank demand entries by miss-count x predicted speedup.

    Runs :func:`predicted_speedup` for each entry against the
    transport's current wisdom and sorts descending by priority (ties
    broken by (kernel, key) so every coordinator agrees). Entries whose
    kernel is unknown on this host are dropped — a coordinator cannot
    rank what it cannot score.

    Example::

        ranked = prioritize(aggregate_demand(bus), bus.transport)
        jobs = coordinator.plan(ranked=ranked)
    """
    out: list[ScenarioPriority] = []
    for entry in entries:
        est = predicted_speedup(entry.kernel, entry.key,
                                transport_wisdom(transport, entry.kernel),
                                n_probes=n_probes, seed=seed)
        if est is None:
            continue
        est.entry = entry
        out.append(est)
    out.sort(key=lambda p: (-p.priority, p.entry.kernel, p.entry.key_str))
    return out
