"""Tuning jobs, shard partitioning, and crash-safe shard leases.

A :class:`TuningJob` is one prioritized (kernel, device, problem, dtype)
scenario turned into work: its config space is deterministically
partitioned into ``n_shards`` disjoint shards
(:meth:`~repro.core.param.ConfigSpace.shard`), each tuned independently
under its own eval budget. The shard set depends only on the job spec —
never on how many workers happen to exist — so the assembled result is
identical whether one worker drains every shard or twenty race for them.

Shards are claimed through *lease* documents on the control bus:

  * a lease is live until ``expires_at`` (heartbeats extend it);
  * claiming is write-then-verify: publish a claim carrying a unique
    nonce, read it back, and only the claimant whose nonce survived the
    last-writer-wins race owns the shard — the same discipline the
    atomic-rename directory transport makes safe for wisdom files;
  * a crashed worker stops heartbeating, its lease expires, and the next
    worker re-claims (``claims`` counts hand-offs); the dead worker's
    checkpointed evaluations (``state`` channel) warm-start the retry.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

from repro.obs import runtime as obs
from repro.online.tracker import ScenarioKey, format_key, parse_key

from .bus import Clock, ControlBus

#: Default lease time-to-live. Workers heartbeat at every checkpoint, so
#: this only bounds how long a crashed worker's shard stays stuck.
LEASE_TTL_S = 60.0


def _lease_event(event: str, worker: str) -> None:
    m = obs.metrics()
    if m is not None:
        m.counter("fleet.lease", event=event, worker=worker).inc()


@dataclass
class TuningJob:
    """One scenario's worth of sharded tuning work.

    The published spec every worker reads: which kernel/scenario to
    tune, with what strategy and per-shard budget, split into
    ``n_shards`` deterministic config-space shards. The shard set is a
    pure function of this spec (never of the worker population), which
    is what makes assembled results schedule-independent.

    Example::

        job = TuningJob(job_id=job_id_for("matmul", key), kernel="matmul",
                        device_kind="tpu-v5e", problem=(256, 256, 256),
                        dtype="float32", n_shards=4)
        bus.publish("job", job.job_id, job.to_json())
    """
    job_id: str
    kernel: str
    device_kind: str
    problem: tuple[int, ...]
    dtype: str
    strategy: str = "exhaustive"
    n_shards: int = 4
    max_evals_per_shard: int = 200
    seed: int = 0
    round_: int = 0
    misses: int = 0            # fleet demand when the job was planned
    order: int = 0             # coordinator priority rank (workers obey)

    def scenario_key(self) -> ScenarioKey:
        return (self.device_kind, tuple(self.problem), self.dtype)

    def shard_ids(self) -> list[str]:
        return [f"s{i:03d}" for i in range(self.n_shards)]

    def shard_index(self, shard_id: str) -> int:
        return int(shard_id[1:])

    def shard_seed(self, shard_id: str) -> int:
        h = hashlib.sha256(
            f"{self.seed}|{self.job_id}|{shard_id}".encode()).digest()
        return int.from_bytes(h[:8], "little")

    def to_json(self) -> dict:
        return {
            "job_id": self.job_id, "kernel": self.kernel,
            "scenario": format_key(self.scenario_key()),
            "strategy": self.strategy, "n_shards": self.n_shards,
            "max_evals_per_shard": self.max_evals_per_shard,
            "seed": self.seed, "round": self.round_,
            "misses": self.misses, "order": self.order,
        }

    @staticmethod
    def from_json(d: dict) -> "TuningJob":
        device_kind, problem, dtype = parse_key(d["scenario"])
        return TuningJob(
            job_id=d["job_id"], kernel=d["kernel"],
            device_kind=device_kind, problem=problem, dtype=dtype,
            strategy=d.get("strategy", "exhaustive"),
            n_shards=int(d.get("n_shards", 4)),
            max_evals_per_shard=int(d.get("max_evals_per_shard", 200)),
            seed=int(d.get("seed", 0)), round_=int(d.get("round", 0)),
            misses=int(d.get("misses", 0)), order=int(d.get("order", 0)))


def job_id_for(kernel: str, key: ScenarioKey, round_: int = 0) -> str:
    """Deterministic job identity: same scenario + round -> same id on
    every coordinator, so concurrent planners collide into one job
    instead of duplicating work.

    Example::

        job_id_for("matmul", ("tpu-v5e", (256, 256, 256), "float32"))
        # -> 'j-<10 hex chars>-r0'
    """
    h = hashlib.sha256(f"{kernel}|{format_key(key)}".encode())
    return f"j-{h.hexdigest()[:10]}-r{round_}"


def list_jobs(bus: ControlBus) -> list[TuningJob]:
    """All published jobs, in coordinator priority order.

    Workers iterate this to find claimable shards (highest-priority
    first); the ``order`` field pins the coordinator's ranking so every
    worker walks jobs in the same sequence.

    Example::

        for job in list_jobs(bus):
            for shard_id in job.shard_ids():
                ...
    """
    jobs = [TuningJob.from_json(d) for d in bus.docs("job")]
    jobs.sort(key=lambda j: (j.order, j.job_id))
    return jobs


# ------------------------------- leases -------------------------------------

def lease_name(job_id: str, shard_id: str) -> str:
    """Canonical ``job--shard`` document name: the shared key under
    which one shard's lease, checkpointed state, and result live on
    their respective channels. Example:
    ``bus.fetch("result", lease_name(job.job_id, "s002"))``."""
    return f"{job_id}--{shard_id}"


@dataclass
class Lease:
    """Ownership claim on one shard (a document on the ``lease`` channel).

    Carries the claimant's identity plus a per-claim ``nonce`` — the
    write-then-verify token that resolves claim races — and
    ``expires_at``, after which a non-heartbeating holder is presumed
    dead and the shard is reclaimable. ``claims`` counts hand-offs
    across crashes.

    Example::

        lease = claim_shard(bus, job, "s000", "w1", clock)
        heartbeat(bus, lease, clock)     # extend while tuning
        release(bus, lease)              # mark done
    """

    job_id: str
    shard_id: str
    worker: str
    nonce: str
    claims: int
    expires_at: float
    state: str = "claimed"     # claimed | done

    def to_json(self) -> dict:
        return {"job": self.job_id, "shard": self.shard_id,
                "worker": self.worker, "nonce": self.nonce,
                "claims": self.claims, "expires_at": self.expires_at,
                "state": self.state}

    @staticmethod
    def from_json(d: dict) -> "Lease":
        return Lease(job_id=d["job"], shard_id=d["shard"],
                     worker=d["worker"], nonce=d["nonce"],
                     claims=int(d.get("claims", 1)),
                     expires_at=float(d.get("expires_at", 0.0)),
                     state=d.get("state", "claimed"))


class LeaseLost(RuntimeError):
    """The shard's lease no longer carries our nonce: it expired and was
    reclaimed (or lost the initial claim race). The holder must abandon
    the shard — the new owner resumes from the last checkpoint.

    Raised by :func:`heartbeat` and :func:`release`; workers catch it
    around the whole shard run (for example
    ``try: ... except LeaseLost: continue``) and move on to the next
    claimable shard.
    """


def fetch_lease(bus: ControlBus, job_id: str, shard_id: str) -> Lease | None:
    """Read one shard's current lease document, or None when the shard
    has never been claimed. Read-only — status displays and claim
    checks use it. Example: ``fetch_lease(bus, job.job_id, "s001")``."""
    doc = bus.fetch("lease", lease_name(job_id, shard_id))
    return Lease.from_json(doc) if doc is not None else None


def _verify_owned(bus: ControlBus, lease: Lease) -> None:
    cur = fetch_lease(bus, lease.job_id, lease.shard_id)
    if cur is None or cur.nonce != lease.nonce:
        _lease_event("lost", lease.worker)
        raise LeaseLost(
            f"{lease.worker} no longer holds "
            f"{lease_name(lease.job_id, lease.shard_id)} "
            f"(now: {cur.nonce if cur else 'gone'})")


def claim_shard(bus: ControlBus, job: TuningJob, shard_id: str,
                worker_id: str, clock: Clock,
                ttl_s: float = LEASE_TTL_S) -> Lease | None:
    """Try to claim one shard. Returns the owned lease, or None when the
    shard is done, live under another worker, or lost to a racing claim.

    The write-then-verify read-back rejects the *observable* race, but
    two claimants interleaving fetch/publish/fetch can both pass it (the
    transport has no exclusive-create). That is wasted work, never
    corruption: :func:`heartbeat` re-verifies ownership at every
    checkpoint, so the overwritten claimant aborts at its next
    checkpoint, and shard results are deterministic and assembly
    idempotent, so even a duplicated shard publishes identical bytes.

    Example::

        lease = claim_shard(bus, job, "s000", "w1", WallClock())
        if lease is not None:
            ...   # we own the shard until lease.expires_at
    """
    cur = fetch_lease(bus, job.job_id, shard_id)
    now = clock.now()
    if cur is not None and (cur.state == "done" or cur.expires_at > now):
        return None
    claims = (cur.claims if cur else 0) + 1
    lease = Lease(job_id=job.job_id, shard_id=shard_id, worker=worker_id,
                  nonce=f"{worker_id}.{claims}", claims=claims,
                  expires_at=now + ttl_s)
    bus.publish("lease", lease_name(job.job_id, shard_id), lease.to_json())
    check = fetch_lease(bus, job.job_id, shard_id)
    if check is not None and check.nonce == lease.nonce \
            and check.worker == worker_id:
        _lease_event("reclaim" if claims > 1 else "acquire", worker_id)
        return check
    _lease_event("race_lost", worker_id)
    return None                 # lost the last-writer-wins race


def heartbeat(bus: ControlBus, lease: Lease, clock: Clock,
              ttl_s: float = LEASE_TTL_S) -> Lease:
    """Extend a held lease's expiry (call at every checkpoint).

    Verifies ownership first and raises :class:`LeaseLost` if the lease
    was reclaimed meanwhile — a stalled worker must never steal back a
    shard another worker is already tuning (that would both duplicate
    work and corrupt the ``claims`` hand-off count).

    Example::

        heartbeat(bus, lease, clock)     # at every checkpoint
    """
    _verify_owned(bus, lease)
    lease.expires_at = clock.now() + ttl_s
    bus.publish("lease", lease_name(lease.job_id, lease.shard_id),
                lease.to_json())
    _lease_event("heartbeat", lease.worker)
    return lease


def release(bus: ControlBus, lease: Lease) -> None:
    """Mark a shard finished; a done lease is never reclaimed.

    Raises :class:`LeaseLost` when the lease was reclaimed meanwhile
    (the new owner, not us, gets to finish the shard). Call only after
    the shard's result document is published, so a "done" lease always
    has a result behind it.

    Example::

        bus.publish("result", name, result_doc)
        release(bus, lease)
    """
    _verify_owned(bus, lease)
    lease.state = "done"
    bus.publish("lease", lease_name(lease.job_id, lease.shard_id),
                lease.to_json())
    _lease_event("release", lease.worker)
