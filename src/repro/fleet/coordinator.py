"""Fleet coordinator: demand -> jobs -> assembled fleet wisdom.

The coordinator closes the orchestration loop:

  plan      aggregate worker demand snapshots, rank scenarios by
            miss-count x predicted speedup, publish a sharded
            :class:`~repro.fleet.jobs.TuningJob` per hot scenario that
            has no finished job at the current demand level;
  assemble  once every shard of a job has a result, pick the winner with
            the *same* deterministic comparator the merge engine uses,
            build a ``fleet``-provenance :class:`WisdomRecord`, and
            fetch-merge-publish it into the transport's wisdom (and an
            optional local store) — the fleet copy only ever improves;
  re-check  demand keeps flowing; a scenario whose misses grew past the
            level its last job was planned at (wisdom regressed, or the
            record stopped matching) is re-enqueued as round N+1.

Everything is deterministic: job identity hashes the scenario, shard
membership hashes configs, winners tie-break through
:func:`~repro.distrib.merge.better_record`, and fleet provenance carries
no timestamps — the same demand assembles to byte-identical wisdom on
any coordinator.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.device import get_device
from repro.core.wisdom import (Wisdom, WisdomRecord, make_fleet_provenance)
from repro.distrib.merge import better_record, merge_wisdom
from repro.distrib.store import CONTROL_PREFIX, WisdomStore
from repro.distrib.sync import transport_wisdom
from repro.obs import runtime as obs_runtime
from repro.online.tracker import format_key
from repro.sandbox.gate import OracleGate

from .bus import ControlBus
from .demand import (aggregate_demand, aggregate_latency, prioritize,
                     seed_demand)
from .jobs import TuningJob, job_id_for, lease_name, list_jobs

#: Misses below this never become a job (the fleet analogue of the online
#: tracker's activation threshold).
MIN_MISSES = 3

#: Observed serve latency above predicted_us x this triggers a
#: verification job for a transferred record. Above the cost model's
#: ~5% measurement noise but tight enough that a genuinely wrong
#: prediction (a config that does not suit the target device) trips it.
TRANSFER_VERIFY_TOLERANCE = 1.2

#: Synthetic demand-snapshot worker id used for verification enqueues.
VERIFY_WORKER = "transfer-verify"


@dataclass
class CoordinatorReport:
    """What one coordination round did (job ids per outcome).

    ``idle`` is the loop's convergence signal: nothing planned,
    assembled, or requeued this round means demand is fully answered.

    Example::

        report = coordinator.tick()
        if report.idle:
            break
    """

    planned: list[str] = field(default_factory=list)    # job ids
    assembled: list[str] = field(default_factory=list)  # job ids
    requeued: list[str] = field(default_factory=list)   # job ids (new round)
    skipped: int = 0                                    # below-threshold
    #: Scenario keys whose transferred records regressed against their
    #: prediction this round and were re-seeded into demand (the jobs
    #: they become show up in ``planned``).
    verify: list[str] = field(default_factory=list)

    @property
    def idle(self) -> bool:
        return not (self.planned or self.assembled or self.requeued)


class Coordinator:
    """Plans jobs from demand and assembles shard results into wisdom.

    Any host can run one (job identity is deterministic, so concurrent
    planners collide into the same jobs instead of duplicating work);
    ``tick()`` is the whole loop — assemble finished jobs, then re-plan
    from fresh demand.

    Example::

        coord = Coordinator(ControlBus(transport), n_shards=4)
        while not coord.tick().idle:
            pass
    """

    def __init__(self, bus: ControlBus, store: WisdomStore | None = None,
                 n_shards: int = 4, max_evals_per_shard: int = 200,
                 strategy: str = "exhaustive", min_misses: int = MIN_MISSES,
                 speedup_probes: int = 16, seed: int = 0, oracle="auto"):
        self.bus = bus
        self.store = store
        self.n_shards = n_shards
        self.max_evals_per_shard = max_evals_per_shard
        self.strategy = strategy
        self.min_misses = min_misses
        self.speedup_probes = speedup_probes
        self.seed = seed
        #: Correctness gate on shard winners: a winner that fails its
        #: reference check never enters fleet wisdom — assembly falls
        #: back to the next-best shard result instead. ``"auto"`` = a
        #: default :class:`OracleGate`; None disables gating.
        self.oracle = OracleGate() if oracle == "auto" else oracle
        #: Coordination rounds run so far; with no wall clock anywhere in
        #: the coordinator, assembled-wisdom age is expressed in rounds.
        self.rounds = 0
        self._last_assembled_round: int | None = None

    # -- planning --------------------------------------------------------------

    def decide(self, entry) -> tuple[str, int, bool] | None:
        """What planning would do for one demand entry, from the cheap
        control documents alone: (job_id, round, is_requeue), or None
        when the entry needs no new job (satisfied, or round in flight).
        """
        round_ = 0
        requeue = False
        while True:
            job_id = job_id_for(entry.kernel, entry.key, round_)
            done = self.bus.fetch("done", job_id)
            if done is None:
                break
            if entry.misses <= int(done.get("misses_at_plan", 0)):
                # demand has not moved since this round finished:
                # wisdom already answers it, nothing to re-tune
                return None
            round_ += 1             # regression: demand outgrew the result
            requeue = True
        if self.bus.fetch("job", job_id) is not None:
            return None             # this round is already in flight
        return job_id, round_, requeue

    def plan(self, report: CoordinatorReport | None = None,
             ranked: list | None = None) -> list[TuningJob]:
        """Turn current fleet demand into published jobs (idempotent: a
        scenario with a live or demand-current finished job is skipped).
        ``ranked`` lets a caller that already ran :func:`prioritize`
        (e.g. to print the ranking) pass it in instead of re-probing."""
        report = report if report is not None else CoordinatorReport()
        if ranked is None:
            # Filter before ranking: the speedup probe costs ~n_probes
            # cost-model evaluations per scenario, and trackers publish
            # *every* scenario they ever saw — in steady state almost all
            # are below threshold or already answered by a finished job.
            actionable = []
            for entry in aggregate_demand(self.bus):
                if entry.misses < self.min_misses:
                    report.skipped += 1
                elif self.decide(entry) is not None:
                    actionable.append(entry)
            ranked = prioritize(actionable, self.bus.transport,
                                n_probes=self.speedup_probes,
                                seed=self.seed) if actionable else []
        jobs: list[TuningJob] = []
        order = len(list_jobs(self.bus))
        for pri in ranked:
            entry = pri.entry
            if entry.misses < self.min_misses:
                report.skipped += 1
                continue
            decision = self.decide(entry)
            if decision is None:
                continue            # satisfied, or round already in flight
            job_id, round_, requeue = decision
            job = TuningJob(
                job_id=job_id, kernel=entry.kernel,
                device_kind=entry.key[0], problem=tuple(entry.key[1]),
                dtype=entry.key[2], strategy=self.strategy,
                n_shards=self.n_shards,
                max_evals_per_shard=self.max_evals_per_shard,
                seed=self.seed, round_=round_, misses=entry.misses,
                order=order)
            order += 1
            self.bus.publish("job", job.job_id, job.to_json())
            jobs.append(job)
            (report.requeued if requeue else report.planned).append(job_id)
        return jobs

    # -- assembly --------------------------------------------------------------

    def assemble(self, report: CoordinatorReport | None = None
                 ) -> list[WisdomRecord]:
        """Fold every fully-tuned job's shard winners into fleet wisdom."""
        report = report if report is not None else CoordinatorReport()
        records: list[WisdomRecord] = []
        for job in list_jobs(self.bus):
            if self.bus.fetch("done", job.job_id) is not None:
                continue
            results = []
            for shard_id in job.shard_ids():
                doc = self.bus.fetch("result",
                                     lease_name(job.job_id, shard_id))
                if doc is None:
                    break
                results.append(doc)
            if len(results) < job.n_shards:
                continue            # still tuning
            record, rejected = self._assemble_job(job, results)
            done = {"job": job.job_id, "misses_at_plan": job.misses,
                    "round": job.round_}
            if record is None:
                done["state"] = "no-winner"
            else:
                done["state"] = "assembled"
                done["score_us"] = record.score_us
                done["config"] = dict(record.config)
                records.append(record)
            if rejected:
                done["rejected"] = rejected
            self.bus.publish("done", job.job_id, done)
            report.assembled.append(job.job_id)
        return records

    def _assemble_job(self, job: TuningJob, results: list[dict]
                      ) -> tuple[WisdomRecord | None, list[dict]]:
        total_evals = sum(int(r.get("evals", 0)) for r in results)
        dev = get_device(job.device_kind)
        provenance = make_fleet_provenance(
            strategy=job.strategy, evals=total_evals,
            objective="costmodel", job_id=job.job_id,
            n_shards=job.n_shards, round_=job.round_)
        candidates: list[WisdomRecord] = []
        for r in results:
            if r.get("best_config") is None:
                continue
            candidates.append(WisdomRecord(
                device_kind=dev.kind, device_family=dev.family,
                problem_size=tuple(job.problem), dtype=job.dtype,
                config=dict(r["best_config"]),
                score_us=float(r["best_score_us"]),
                provenance=dict(provenance)))
        # Walk shard winners best-first through the correctness gate: a
        # shard whose "winner" computes the wrong answer (crashed tuner,
        # cost-model blind spot) is recorded in the done doc and the
        # next-best shard result takes its place.
        winner: WisdomRecord | None = None
        rejected: list[dict] = []
        while candidates:
            best_i = 0
            for i in range(1, len(candidates)):
                if better_record(candidates[best_i],
                                 candidates[i]) is candidates[i]:
                    best_i = i
            cand = candidates.pop(best_i)
            if self.oracle is None:
                winner = cand
                break
            verdict = self.oracle.check_record(job.kernel, cand)
            if self.oracle.allows(verdict):
                stamped = self.oracle.stamp(cand.provenance, job.kernel,
                                            verdict)
                winner = (cand if stamped == cand.provenance else
                          WisdomRecord(
                              device_kind=cand.device_kind,
                              device_family=cand.device_family,
                              problem_size=cand.problem_size,
                              dtype=cand.dtype, config=dict(cand.config),
                              score_us=cand.score_us, provenance=stamped))
                break
            rejected.append({"config": dict(cand.config),
                             "score_us": cand.score_us,
                             "verdict": verdict.to_json()})
        if winner is None:
            # every shard came back infeasible, or the oracle vetoed all
            return None, rejected
        # Shard winners flow through the merge engine into fleet wisdom:
        # fetch-merge-publish, so a better record already on the transport
        # (another job round, an online promotion) survives.
        merged = merge_wisdom(Wisdom(job.kernel, [winner]),
                              transport_wisdom(self.bus.transport,
                                               job.kernel))
        self.bus.transport.publish(job.kernel, merged.to_doc())
        if self.store is not None:
            self.store.save(merge_wisdom(self.store.load(job.kernel),
                                         merged))
        return winner, rejected

    # -- transfer verification -------------------------------------------------

    def check_transfers(self, report: CoordinatorReport | None = None
                        ) -> list[str]:
        """Enqueue verification tuning for regressed transferred records.

        Compares each transferred record on the transport (provenance
        ``predicted_us``) against the fleet's best observed serve latency
        for its scenario (``latency`` channel). An observation worse than
        prediction x ``TRANSFER_VERIFY_TOLERANCE`` means the prediction
        is not holding on real traffic: the scenario is re-seeded into
        demand under the ``transfer-verify`` worker id, so the very next
        ``plan()`` turns it into an ordinary tuning job — and the
        assembled *measured* record beats the transferred one in every
        merge, completing predict -> verify -> promote.

        Example::

            publish_latency(bus, "host-1", {"matmul": {key_str: 712.0}})
            coordinator.tick()        # runs check_transfers + plan
        """
        report = report if report is not None else CoordinatorReport()
        observed = aggregate_latency(self.bus)
        if not observed:
            return []
        # Only kernels somebody actually observed: latency docs persist
        # across ticks, and fetching + migrating every kernel's wisdom on
        # every tick would make the daemon loop O(kernels x records) I/O.
        watched = sorted({kernel for kernel, _key in observed})
        published = set(self.bus.transport.list_kernels())
        regressed: list[tuple[str, tuple, int]] = []
        for name in watched:
            if name.startswith(CONTROL_PREFIX) or name not in published:
                continue
            for rec in transport_wisdom(self.bus.transport, name).records:
                if not rec.is_transferred():
                    continue
                key = (rec.device_kind, rec.problem_size, rec.dtype)
                obs = observed.get((name, format_key(key)))
                if obs is None:
                    continue
                try:
                    predicted = float(rec.provenance.get("predicted_us",
                                                         rec.score_us))
                except (TypeError, ValueError):
                    predicted = rec.score_us
                if obs > predicted * TRANSFER_VERIFY_TOLERANCE:
                    regressed.append((name, key, self.min_misses))
        keys = [format_key(k) for _, k, _ in regressed]
        if regressed:
            seed_demand(self.bus, VERIFY_WORKER, regressed)
            report.verify.extend(keys)
        return keys

    # -- the loop --------------------------------------------------------------

    def tick(self) -> CoordinatorReport:
        """One coordination round: assemble finished jobs, check
        transferred-wisdom predictions against observed latency, then
        re-check demand (hot or regressed scenarios get (re-)enqueued)."""
        report = CoordinatorReport()
        self.assemble(report)
        self.check_transfers(report)
        self.plan(report)
        self.rounds += 1
        if report.assembled:
            self._last_assembled_round = self.rounds
        m = obs_runtime.metrics()
        if m is not None:
            for event, ids in (("planned", report.planned),
                               ("assembled", report.assembled),
                               ("requeued", report.requeued),
                               ("verify", report.verify)):
                if ids:
                    m.counter("fleet.jobs", event=event).inc(len(ids))
            m.gauge("fleet.rounds").set(self.rounds)
            # Rounds since fleet wisdom last changed: fresh wisdom is
            # age 0; "never assembled anything" reads as age == rounds.
            age = (self.rounds - self._last_assembled_round
                   if self._last_assembled_round is not None
                   else self.rounds)
            m.gauge("fleet.assembled_age_rounds").set(age)
        return report

    # -- introspection ---------------------------------------------------------

    def fleet_health(self, top: int = 10) -> str:
        """The wisdom-health report over every snapshot workers have
        published on the ``metrics`` channel (see
        :mod:`repro.fleet.health`). Example: ``print(coord.fleet_health())``
        after a few ticks shows fleet-wide hit rates and missing
        scenarios."""
        from .health import fleet_health
        return fleet_health(self.bus, top=top)

    def status(self) -> dict:
        demand = aggregate_demand(self.bus)
        jobs = list_jobs(self.bus)
        done = {d["job"]: d for d in self.bus.docs("done")}
        shard_results = len(self.bus.names("result"))
        return {
            "demand_entries": len(demand),
            "demand_misses": sum(e.misses for e in demand),
            "jobs": len(jobs),
            "jobs_done": len(done),
            "jobs_open": len([j for j in jobs if j.job_id not in done]),
            "shard_results": shard_results,
            "scenarios": [
                {"kernel": e.kernel, "key": format_key(e.key),
                 "misses": e.misses, "workers": e.workers}
                for e in demand],
        }
