"""Control bus: typed fleet-control channels over a wisdom Transport.

The orchestrator needs to move four kinds of control documents between
workers and the coordinator: demand snapshots, job specs, shard leases,
and shard results. Rather than invent a second rendezvous mechanism, they
ride the *same* :class:`~repro.distrib.sync.Transport` the wisdom files
do — a shared directory (or the in-memory test transport) the operator
already has — under the reserved ``CONTROL_PREFIX`` namespace the wisdom
sync layer skips. One mount point, one permission model, one thing to
rsync.

Names are ``fleet--<channel>--<name>``; ``name`` must be filename-safe
(the directory transport stores one file per document).

Time is injected (:class:`Clock`) so lease expiry — the one place the
orchestrator depends on wall clock — is deterministic under test
(:class:`ManualClock`) and real in production (:class:`WallClock`).
"""

from __future__ import annotations

import re
import time
from typing import Protocol

from repro.distrib.store import CONTROL_PREFIX
from repro.distrib.sync import Transport

_NAME_RE = re.compile(r"^[A-Za-z0-9._-]+$")
_SEP = "--"

#: Channels the orchestrator uses (documentation; the bus accepts any
#: filename-safe channel string). ``latency`` carries serving hosts'
#: observed per-scenario latencies — the signal the coordinator checks
#: transferred records' predictions against (repro.transfer);
#: ``metrics`` carries per-host observability snapshots
#: (repro.fleet.health) the coordinator merges into fleet-wide health.
CHANNELS = ("demand", "job", "lease", "state", "result", "done", "latency",
            "metrics")


def _check(kind: str, value: str) -> str:
    if not _NAME_RE.match(value) or _SEP in value:
        raise ValueError(f"{kind} {value!r} is not transport-safe "
                         f"(allowed: [A-Za-z0-9._-], no {_SEP!r})")
    return value


class Clock(Protocol):
    """Injectable time source: anything with ``now() -> float`` seconds.

    The orchestrator touches wall clock in exactly one place — lease
    expiry — and always through this protocol, so production uses
    :class:`WallClock` and tests drive expiry deterministically with a
    :class:`ManualClock`. Example: ``claim_shard(bus, job, "s000",
    "w1", clock)``.
    """

    def now(self) -> float: ...


class WallClock:
    """Real time (``time.time``) — the production :class:`Clock`.

    The default everywhere a clock is optional; only tests and the
    deterministic local harness substitute something else.
    Example: ``FleetWorker(bus, "host-1", clock=WallClock())``.
    """

    def now(self) -> float:
        return time.time()


class ManualClock:
    """Logical time advanced explicitly — the deterministic :class:`Clock`.

    ``advance(dt)`` is the only way time moves, which makes lease
    expiry (and therefore crash-reclaim scheduling) a pure function of
    the test script rather than host speed.

    Example::

        clock = ManualClock()
        lease = claim_shard(bus, job, "s000", "w1", clock)
        clock.advance(LEASE_TTL_S + 1)      # w1's lease is now expired
    """

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


class ControlBus:
    """Publish/fetch/list fleet control documents on a transport.

    The single rendezvous abstraction of the orchestrator: demand
    snapshots, job specs, leases, checkpoints and results are all just
    JSON documents on named channels, stored through whatever wisdom
    :class:`~repro.distrib.sync.Transport` the deployment already has
    (a shared directory in production, memory in tests) under the
    reserved ``fleet--`` namespace.

    Example::

        bus = ControlBus(DirectoryTransport("/mnt/shared/wisdom"))
        bus.publish("demand", "host-1", {"worker": "host-1", ...})
        docs = bus.docs("demand")
    """

    def __init__(self, transport: Transport):
        self.transport = transport

    @staticmethod
    def key(channel: str, name: str) -> str:
        return CONTROL_PREFIX + _check("channel", channel) + _SEP + name

    def publish(self, channel: str, name: str, doc: dict) -> None:
        _check("name", name.replace(_SEP, "."))   # segments must be safe
        self.transport.publish(self.key(channel, name), doc)

    def fetch(self, channel: str, name: str) -> dict | None:
        return self.transport.fetch(self.key(channel, name))

    def names(self, channel: str) -> list[str]:
        """Document names present on ``channel``, sorted."""
        prefix = CONTROL_PREFIX + _check("channel", channel) + _SEP
        return sorted(n[len(prefix):]
                      for n in self.transport.list_kernels()
                      if n.startswith(prefix))

    def docs(self, channel: str) -> list[dict]:
        """Every document on ``channel``, in name order (skipping any that
        vanished between list and fetch — transports are shared)."""
        out = []
        for name in self.names(channel):
            doc = self.fetch(channel, name)
            if doc is not None:
                out.append(doc)
        return out
