"""``python -m repro.fleet`` — operate the fleet tuning orchestrator.

The rendezvous point is a shared wisdom directory (the same one
``python -m repro.wisdom`` manages and serving hosts PullSync from);
control documents live beside the wisdom files under the reserved
``fleet--`` namespace. Subcommands:

  plan        aggregate demand, rank scenarios, publish tuning jobs
              (``--dry-run`` prints the plan without publishing)
  coordinate  run coordination rounds: assemble finished jobs into fleet
              wisdom, then re-plan from fresh demand
  work        run a worker loop: claim shard leases, tune, checkpoint
  status      one-screen summary of demand / jobs / leases / results
  demo        run the in-process reference fleet (run_local_fleet) —
              the zero-setup way to watch the whole loop

A real deployment runs ``work`` on every tuning host, ``coordinate`` on
one (any) host, and whatever serves traffic keeps publishing demand.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.distrib.store import WisdomStore
from repro.distrib.sync import DirectoryTransport

from .bus import ControlBus
from .coordinator import MIN_MISSES, Coordinator
from .demand import aggregate_demand, prioritize
from .health import MetricsPublisher, fleet_snapshots
from .jobs import LEASE_TTL_S, fetch_lease, lease_name, list_jobs
from .local import run_local_fleet
from .worker import FleetWorker


def _bus(args) -> ControlBus:
    return ControlBus(DirectoryTransport(args.dir))


def _coordinator(args, bus: ControlBus) -> Coordinator:
    return Coordinator(bus, n_shards=args.shards,
                       max_evals_per_shard=args.evals_per_shard,
                       strategy=args.strategy, min_misses=args.min_misses,
                       seed=args.seed)


def _cmd_plan(args) -> int:
    bus = _bus(args)
    coord = _coordinator(args, bus)
    # Filter before ranking, like Coordinator.plan: the speedup probe
    # costs ~16 cost-model evals per scenario, and in steady state most
    # published scenarios are below threshold or already answered.
    entries = aggregate_demand(bus)
    actionable = [e for e in entries
                  if e.misses >= args.min_misses
                  and coord.decide(e) is not None]
    ranked = prioritize(actionable, bus.transport, seed=args.seed)
    if not entries:
        print("no demand published")
        return 0
    for p in ranked:
        e = p.entry
        print(f"{e.kernel} {e.key_str}: misses={e.misses} "
              f"workers={e.workers} speedup~{p.speedup:.2f}x "
              f"priority={p.priority:.1f}")
    if len(entries) > len(actionable):
        print(f"({len(entries) - len(actionable)} scenario(s) below "
              f"threshold or already answered)")
    if args.dry_run:
        print("(dry run: no jobs published)")
        return 0
    jobs = coord.plan(ranked=ranked)
    for job in jobs:
        print(f"planned {job.job_id}: {job.kernel} "
              f"{job.n_shards} shard(s) x {job.max_evals_per_shard} evals "
              f"({job.strategy})")
    print(f"{len(jobs)} job(s) published")
    return 0


def _cmd_coordinate(args) -> int:
    bus = _bus(args)
    coord = _coordinator(args, bus)
    for i in range(args.rounds):
        report = coord.tick()
        print(f"round {i}: assembled={len(report.assembled)} "
              f"planned={len(report.planned)} "
              f"requeued={len(report.requeued)} "
              f"verify={len(report.verify)}")
        if report.idle:
            break
    print(json.dumps(coord.status(), indent=2))
    return 0


def _cmd_work(args) -> int:
    bus = _bus(args)
    datasets = None
    if args.dataset_dir is not None:
        from repro.tunebench import DatasetStore
        datasets = DatasetStore(args.dataset_dir)
    worker = FleetWorker(bus, args.worker_id, ttl_s=args.ttl,
                         checkpoint_every=args.checkpoint_every,
                         datasets=datasets)
    # One-shot drain exits once nothing is claimable *right now*. With
    # --poll the worker keeps watching while any shard still lacks a
    # result, so a peer's crashed shard is reclaimed when its lease
    # expires — without it, crash recovery needs a supervisor restarting
    # this command. (Assembly is the coordinator's job: a worker must not
    # wait on it, or the two one-shot commands would deadlock.)
    def unfinished_shards() -> bool:
        return any(
            bus.fetch("result", lease_name(j.job_id, s)) is None
            for j in list_jobs(bus)
            if bus.fetch("done", j.job_id) is None
            for s in j.shard_ids())

    # When observability is enabled (KERNEL_LAUNCHER_OBS=1 or
    # repro.obs.enable()), every drain publishes this worker's metrics
    # snapshot onto the control bus so any host can render fleet-wide
    # health with ``python -m repro.obs report --bus DIR``. A no-op
    # while disabled.
    publisher = MetricsPublisher(bus, args.worker_id, interval=1)
    n = worker.drain(max_shards=args.max_shards)
    publisher.tick()
    while args.poll is not None:
        if args.max_shards is not None and n >= args.max_shards:
            break
        if not unfinished_shards():
            break
        time.sleep(args.poll)
        n += worker.drain(max_shards=(None if args.max_shards is None
                                      else args.max_shards - n))
        publisher.tick()
    print(f"{args.worker_id}: finished {n} shard(s), "
          f"{worker.evals_run} evaluation(s)")
    for name in worker.shards_done:
        print(f"  {name}")
    return 0


def _cmd_status(args) -> int:
    bus = _bus(args)
    coord = Coordinator(bus)
    status = coord.status()
    print(f"{args.dir}: {status['demand_entries']} demand entr(ies), "
          f"{status['demand_misses']} miss(es), {status['jobs']} job(s) "
          f"({status['jobs_open']} open), "
          f"{status['shard_results']} shard result(s)")
    for s in status["scenarios"]:
        print(f"  demand {s['kernel']} {s['key']}: misses={s['misses']} "
              f"from {s['workers']} worker(s)")
    for job in list_jobs(bus):
        states = []
        for shard_id in job.shard_ids():
            if bus.fetch("result", lease_name(job.job_id, shard_id)):
                states.append("done")
                continue
            lease = fetch_lease(bus, job.job_id, shard_id)
            states.append(f"leased:{lease.worker}" if lease else "open")
        done = bus.fetch("done", job.job_id)
        tail = (f" -> {done['state']}" if done else "")
        print(f"  job {job.job_id} {job.kernel} "
              f"[{' '.join(states)}]{tail}")
    snaps = fleet_snapshots(bus)
    if snaps:
        print(f"  {len(snaps)} metrics snapshot(s) on the bus from "
              f"{', '.join(sorted(snaps))} "
              f"(render: python -m repro.obs report --bus {args.dir})")
    return 0


def _cmd_demo(args) -> int:
    report = run_local_fleet(n_workers=args.workers,
                             n_shards=args.shards,
                             strategy=args.strategy,
                             max_evals_per_shard=args.evals_per_shard,
                             min_misses=args.min_misses, seed=args.seed)
    print(f"{report.n_workers} worker(s): {report.steps} shard(s) run, "
          f"{report.total_evals} evaluation(s) "
          f"(busiest worker {report.makespan_evals})")
    for worker, shards in sorted(report.shards_by_worker.items()):
        print(f"  {worker}: {len(shards)} shard(s), "
              f"{report.evals_by_worker[worker]} eval(s)")
    for kernel, doc in sorted(report.wisdom_docs.items()):
        for rec in doc.get("records", []):
            print(f"  wisdom {kernel}: {rec['score_us']:.2f}us "
                  f"config={rec['config']}")
    return 0


def _add_tuning_args(p) -> None:
    p.add_argument("--shards", type=int, default=4,
                   help="shards per job (fixed per job, not per worker)")
    p.add_argument("--evals-per-shard", type=int, default=200)
    p.add_argument("--strategy", default="exhaustive",
                   choices=("exhaustive", "random", "bayes", "anneal"))
    p.add_argument("--min-misses", type=int, default=MIN_MISSES)
    p.add_argument("--seed", type=int, default=0)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Fleet tuning orchestrator: demand-driven, sharded, "
                    "resumable tuning jobs over a shared wisdom directory.")
    sub = ap.add_subparsers(dest="command", required=True)

    def add_dir(p):
        p.add_argument("--dir", default="wisdom",
                       help="shared wisdom/control directory "
                            "(default: ./wisdom)")

    p = sub.add_parser("plan", help="rank demand and publish tuning jobs")
    add_dir(p)
    _add_tuning_args(p)
    p.add_argument("--dry-run", action="store_true",
                   help="print the scenario plan without publishing jobs")
    p.set_defaults(fn=_cmd_plan)

    p = sub.add_parser("coordinate",
                       help="assemble finished jobs, re-plan from demand")
    add_dir(p)
    _add_tuning_args(p)
    p.add_argument("--rounds", type=int, default=1)
    p.set_defaults(fn=_cmd_coordinate)

    p = sub.add_parser("work", help="claim and tune open shards")
    add_dir(p)
    p.add_argument("--worker-id", required=True,
                   help="stable identity for leases (e.g. the hostname)")
    p.add_argument("--max-shards", type=int, default=None)
    p.add_argument("--ttl", type=float, default=LEASE_TTL_S)
    p.add_argument("--checkpoint-every", type=int, default=8)
    p.add_argument("--dataset-dir", default=None, metavar="DIR",
                   help="recorded tuning-space datasets "
                        "(repro.tunebench): shard sessions replay "
                        "matching recorded evaluations instead of "
                        "re-measuring them")
    p.add_argument("--poll", type=float, default=None, metavar="SECONDS",
                   help="keep polling for claimable shards (incl. expired "
                        "leases of crashed peers) until no unfinished "
                        "shard remains; default is a one-shot drain")
    p.set_defaults(fn=_cmd_work)

    p = sub.add_parser("status", help="summarize demand/jobs/leases")
    add_dir(p)
    p.set_defaults(fn=_cmd_status)

    p = sub.add_parser("demo",
                       help="run the in-process reference fleet "
                            "(MemoryTransport, deterministic)")
    p.add_argument("--workers", type=int, default=3)
    _add_tuning_args(p)
    p.set_defaults(fn=_cmd_demo)

    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
