"""Fleet tuning orchestrator: demand-driven, sharded, resumable jobs.

Beyond-paper subsystem (connects PR 1's online demand tracking with PR
2's wisdom distribution). The paper's workflow tunes one machine at a
time; at fleet scale the search itself must be distributed. This package
is the engine that decides *what to tune next, where, and survives
interruption*:

* :mod:`.bus`         — control channels (demand/job/lease/state/result)
  over the existing wisdom sync transports, plus injectable clocks;
* :mod:`.demand`      — aggregate worker ``ScenarioTracker`` snapshots,
  rank scenarios by miss-count x cost-model predicted speedup; aggregate
  serving hosts' observed latencies (the transfer verification signal);
* :mod:`.jobs`        — :class:`TuningJob` specs, deterministic config-
  space shards, crash-safe lease claim/heartbeat/expiry;
* :mod:`.worker`      — :class:`FleetWorker`: claim a shard, tune it with
  checkpointed (warm-startable) strategy sessions, publish the result;
* :mod:`.coordinator` — :class:`Coordinator`: plan jobs from demand,
  assemble shard winners into ``fleet``-provenance wisdom through the
  merge engine, re-enqueue scenarios whose demand regresses;
* :mod:`.local`       — :func:`run_local_fleet`: N in-process workers
  over a ``MemoryTransport``, the deterministic reference harness;
* :mod:`.health`      — per-host metric snapshots on the ``metrics``
  channel, merged into fleet-wide wisdom health (repro.obs);
* :mod:`.cli`         — ``python -m repro.fleet``
  (plan / coordinate / work / status / demo).
"""

from .bus import CHANNELS, Clock, ControlBus, ManualClock, WallClock
from .coordinator import (MIN_MISSES, TRANSFER_VERIFY_TOLERANCE, Coordinator,
                          CoordinatorReport)
from .demand import (DemandEntry, ScenarioPriority, aggregate_demand,
                     aggregate_latency, predicted_speedup, prioritize,
                     publish_demand, publish_latency, seed_demand)
from .health import (METRICS_CHANNEL, MetricsPublisher,
                     aggregate_fleet_metrics, fleet_health, fleet_snapshots,
                     publish_metrics)
from .jobs import (LEASE_TTL_S, Lease, LeaseLost, TuningJob, claim_shard,
                   fetch_lease, heartbeat, job_id_for, lease_name,
                   list_jobs, release)
from .local import DEMO_DEMAND, FleetRunReport, run_local_fleet
from .worker import FleetWorker, WorkerCrash

__all__ = [
    "CHANNELS", "Clock", "ControlBus", "ManualClock", "WallClock",
    "MIN_MISSES", "TRANSFER_VERIFY_TOLERANCE", "Coordinator",
    "CoordinatorReport",
    "DemandEntry", "ScenarioPriority", "aggregate_demand",
    "aggregate_latency", "predicted_speedup", "prioritize",
    "publish_demand", "publish_latency", "seed_demand",
    "METRICS_CHANNEL", "MetricsPublisher", "aggregate_fleet_metrics",
    "fleet_health", "fleet_snapshots", "publish_metrics",
    "LEASE_TTL_S", "Lease", "LeaseLost", "TuningJob", "claim_shard",
    "fetch_lease", "heartbeat", "job_id_for", "lease_name", "list_jobs",
    "release",
    "DEMO_DEMAND", "FleetRunReport", "run_local_fleet",
    "FleetWorker", "WorkerCrash",
]
