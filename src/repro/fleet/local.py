"""Single-process fleet harness: N workers, one MemoryTransport.

``run_local_fleet`` spins a coordinator plus N in-process workers over an
in-memory transport and a logical clock, stepping them round-robin until
the demand table is drained. Deterministic by construction — no threads,
no wall clock — which makes it the reference for the orchestration
semantics (the e2e test asserts byte-identical wisdom for 1 worker vs 3
workers with a forced crash) and the engine behind the CI smoke job and
``benchmarks/fleet_tuning.py``.

A worker "step" claims and fully runs one shard; the round-robin order is
fixed, so the only scheduling freedom — which worker gets which shard —
is exercised while the *result* stays provably schedule-independent.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.distrib.store import CONTROL_PREFIX, WisdomStore
from repro.distrib.sync import MemoryTransport, Transport
from repro.online.tracker import ScenarioKey

from .bus import ControlBus, ManualClock
from .coordinator import Coordinator
from .demand import seed_demand
from .jobs import (LEASE_TTL_S, Lease, fetch_lease, lease_name, list_jobs)
from .worker import FleetWorker, WorkerCrash

#: Demand used when the caller provides none — the quickstart scenario.
DEMO_DEMAND: list[tuple[str, ScenarioKey, int]] = [
    ("matmul", ("tpu-v5e", (256, 256, 256), "float32"), 5),
    ("matmul", ("tpu-v5e", (512, 512, 512), "bfloat16"), 3),
]


@dataclass
class FleetRunReport:
    """What one local fleet run did, for assertions and CSV rows.

    Collects per-worker shard/eval tallies, final lease states, every
    assembled wisdom document, and the coordinator's status — enough to
    assert the orchestration invariants (disjoint shards, single-claim
    leases, byte-identical wisdom) without re-reading the transport.

    Example::

        report = run_local_fleet(n_workers=3)
        assert report.jobs_assembled
        assert all(l.claims == 1 for l in report.leases.values())
    """
    transport: Transport = None
    n_workers: int = 0
    steps: int = 0
    crashes: int = 0
    jobs_planned: list[str] = field(default_factory=list)
    jobs_assembled: list[str] = field(default_factory=list)
    shards_by_worker: dict[str, list[str]] = field(default_factory=dict)
    evals_by_worker: dict[str, int] = field(default_factory=dict)
    leases: dict[str, Lease] = field(default_factory=dict)
    wisdom_docs: dict[str, dict] = field(default_factory=dict)
    status: dict = field(default_factory=dict)

    @property
    def total_evals(self) -> int:
        return sum(self.evals_by_worker.values())

    @property
    def makespan_evals(self) -> int:
        """Critical-path length: evaluations run by the busiest worker.
        The simulated-parallelism analogue of wall time (every worker in
        the real fleet runs concurrently)."""
        return max(self.evals_by_worker.values(), default=0)

    def claims(self) -> dict[str, int]:
        return {name: lease.claims for name, lease in self.leases.items()}


def run_local_fleet(n_workers: int = 3,
                    demand: list[tuple[str, ScenarioKey, int]] | None = None,
                    transport: Transport | None = None, *,
                    store: WisdomStore | None = None,
                    n_shards: int = 4, strategy: str = "exhaustive",
                    max_evals_per_shard: int = 10_000, seed: int = 0,
                    min_misses: int = 3, checkpoint_every: int = 8,
                    crash_worker: str | None = None,
                    crash_after_evals: int | None = None,
                    ttl_s: float = LEASE_TTL_S,
                    max_steps: int = 10_000) -> FleetRunReport:
    """Drain ``demand`` with ``n_workers`` in-process workers.

    ``crash_worker``/``crash_after_evals`` kill one worker mid-shard; the
    run still completes (lease expiry + warm-start reclaim) as long as at
    least one worker survives.

    Example::

        report = run_local_fleet(n_workers=3, crash_worker="w1",
                                 crash_after_evals=13)
        assert report.crashes == 1 and report.jobs_assembled
    """
    transport = transport if transport is not None else MemoryTransport()
    bus = ControlBus(transport)
    clock = ManualClock()
    seed_demand(bus, "seed", demand if demand is not None else DEMO_DEMAND)

    coordinator = Coordinator(bus, store=store,
                              n_shards=n_shards, strategy=strategy,
                              max_evals_per_shard=max_evals_per_shard,
                              min_misses=min_misses, seed=seed)
    workers = [
        FleetWorker(bus, f"w{i}", clock=clock, ttl_s=ttl_s,
                    checkpoint_every=checkpoint_every,
                    crash_after_evals=(crash_after_evals
                                       if f"w{i}" == crash_worker else None))
        for i in range(n_workers)]
    alive = {w.worker_id for w in workers}

    report = FleetRunReport(transport=transport, n_workers=n_workers)
    report.jobs_planned = [j.job_id for j in coordinator.plan()]

    advanced_while_idle = False
    while report.steps < max_steps:
        progressed = False
        for w in workers:
            if w.worker_id not in alive:
                continue
            try:
                done = w.run_once()
            except WorkerCrash:
                # the dead worker's lease now has to age out before the
                # shard is claimable again
                alive.discard(w.worker_id)
                report.crashes += 1
                clock.advance(ttl_s + 1.0)
                progressed = True
                continue
            if done is not None:
                report.steps += 1
                progressed = True
        round_report = coordinator.tick()
        report.jobs_assembled.extend(round_report.assembled)
        report.jobs_planned.extend(round_report.planned
                                   + round_report.requeued)
        if progressed:
            advanced_while_idle = False
            continue
        if not alive:
            break
        if advanced_while_idle:
            break               # idle across a full TTL: nothing left
        clock.advance(ttl_s + 1.0)
        advanced_while_idle = True

    for w in workers:
        report.shards_by_worker[w.worker_id] = list(w.shards_done)
        report.evals_by_worker[w.worker_id] = w.evals_run
    for job in list_jobs(bus):
        for shard_id in job.shard_ids():
            lease = fetch_lease(bus, job.job_id, shard_id)
            if lease is not None:
                report.leases[lease_name(job.job_id, shard_id)] = lease
    report.wisdom_docs = {
        name: transport.fetch(name)
        for name in transport.list_kernels()
        if not name.startswith(CONTROL_PREFIX)}
    report.status = coordinator.status()
    return report
