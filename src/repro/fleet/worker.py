"""Fleet worker: claim a shard lease, tune it, checkpoint, publish.

One :meth:`FleetWorker.run_once` call claims the highest-priority open
shard, tunes that shard of the job's config space against the cost model,
and publishes the shard result. Long shards are crash-safe:

  * every ``checkpoint_every`` live evaluations the worker publishes its
    evaluation log on the ``state`` channel and heartbeats its lease;
  * if the worker dies, the lease expires and another worker re-claims;
    the recorded evaluations warm-start the strategy
    (:mod:`repro.tuner.strategies` replays them), so the retry continues
    from the checkpoint instead of re-measuring the prefix — and, same
    seed, proposes exactly the configs the dead worker would have.

Configs outside the shard are rejected before they reach the evaluator,
so shards stay disjoint even for strategies whose proposals are not
drawn from the shard space (annealing starts at the space default).

With a :class:`~repro.tunebench.DatasetStore` attached (``datasets=``),
a worker additionally warm-starts each shard from the scenario's
*recorded tuning-space dataset*: entries that fall inside the shard are
replayed instead of re-measured (the same history plumbing crash
recovery uses), so a fleet that has tuned a scenario before never pays
for the same evaluation twice.
"""

from __future__ import annotations

import numpy as np

from repro.core.device import get_device
from repro.core.param import Config
from repro.core.registry import get_kernel
from repro.obs import runtime as obs
from repro.sandbox.evaluator import SandboxedEvaluator, SandboxSettings
from repro.tuner.costmodel import INFEASIBLE
from repro.tuner.runner import CostModelEvaluator, EvalResult
from repro.tuner.strategies import (STRATEGIES, Evaluation, TuningResult,
                                    evaluation_from_json, evaluation_to_json)

from .bus import Clock, ControlBus, WallClock
from .jobs import (LEASE_TTL_S, Lease, LeaseLost, TuningJob, claim_shard,
                   heartbeat, lease_name, list_jobs, release)


class WorkerCrash(RuntimeError):
    """Injected mid-shard failure (tests / chaos drills).

    Raised by a worker constructed with ``crash_after_evals=N`` once its
    next shard has run N live evaluations — *after* checkpointing them,
    so the crash loses no measured work. The crash/reclaim e2e tests use
    it to prove byte-identical assembly across worker deaths.

    Example::

        run_local_fleet(n_workers=3, crash_worker="w1",
                        crash_after_evals=13)   # raises + reclaims inside
    """


class FleetWorker:
    """Claims and runs one shard at a time from the control bus.

    The work loop is ``run_once`` (claim the highest-priority open
    shard, tune it, publish) or ``drain`` (repeat until nothing is
    claimable). Crash safety comes from lease heartbeats plus
    checkpointed evaluation logs; ``datasets`` adds recorded-space
    warm starts on top.

    Example::

        bus = ControlBus(DirectoryTransport("/mnt/shared/wisdom"))
        worker = FleetWorker(bus, worker_id="host-3",
                             datasets=DatasetStore("datasets"))
        worker.drain()
    """

    def __init__(self, bus: ControlBus, worker_id: str,
                 clock: Clock | None = None, ttl_s: float = LEASE_TTL_S,
                 checkpoint_every: int = 8,
                 crash_after_evals: int | None = None,
                 datasets=None, evaluator_factory=None,
                 sandbox: SandboxSettings | None = None):
        self.bus = bus
        self.worker_id = worker_id
        self.clock = clock or WallClock()
        self.ttl_s = ttl_s
        self.checkpoint_every = checkpoint_every
        #: When set, raise WorkerCrash after this many live evaluations in
        #: the next shard (one-shot — consumed by the crash).
        self.crash_after_evals = crash_after_evals
        #: Optional repro.tunebench DatasetStore: recorded spaces
        #: warm-start shard sessions (replayed, never re-measured).
        self.datasets = datasets
        #: Optional ``(builder, job) -> Evaluate`` override; default is a
        #: CostModelEvaluator for the job's scenario. Fault-injection
        #: tests swap in misbehaving evaluators here.
        self.evaluator_factory = evaluator_factory
        #: Crash-isolation settings for shard evaluations. Default is
        #: the inline sandbox (verdict classification without a child
        #: process — the cost model cannot hang); pass fork
        #: SandboxSettings when the evaluator itself might hang or
        #: take the worker process down.
        self.sandbox = sandbox if sandbox is not None else SandboxSettings(
            method="inline")
        self.shards_done: list[str] = []
        self.evals_run = 0

    # -- the work loop ---------------------------------------------------------

    def run_once(self) -> str | None:
        """Claim and finish one open shard; returns its ``job--shard``
        name, or None when no shard is claimable right now."""
        for job in list_jobs(self.bus):
            if self.bus.fetch("done", job.job_id) is not None:
                continue                # assembled: no open shards left
            try:
                get_kernel(job.kernel)
            except KeyError:
                # Heterogeneous fleet: this host does not have the job's
                # kernel. Skip BEFORE claiming — crashing with the lease
                # held would stall the shard a full TTL per restart.
                continue
            for shard_id in job.shard_ids():
                if self.bus.fetch("result",
                                  lease_name(job.job_id, shard_id)):
                    continue            # already finished by someone
                lease = claim_shard(self.bus, job, shard_id,
                                    self.worker_id, self.clock, self.ttl_s)
                if lease is None:
                    continue
                tr = obs.tracer()
                try:
                    if tr is not None:
                        with tr.span("fleet.shard", cat="fleet",
                                     job=job.job_id, shard=shard_id,
                                     worker=self.worker_id):
                            self._run_shard(job, shard_id, lease)
                    else:
                        self._run_shard(job, shard_id, lease)
                except LeaseLost:
                    continue            # reclaimed under us: theirs now
                name = lease_name(job.job_id, shard_id)
                self.shards_done.append(name)
                m = obs.metrics()
                if m is not None:
                    m.counter("fleet.shards_done",
                              worker=self.worker_id).inc()
                return name
        return None

    def drain(self, max_shards: int | None = None) -> int:
        """Run shards until none are claimable; returns how many ran."""
        n = 0
        while max_shards is None or n < max_shards:
            if self.run_once() is None:
                break
            n += 1
        return n

    # -- one shard -------------------------------------------------------------

    def _run_shard(self, job: TuningJob, shard_id: str,
                   lease: Lease) -> None:
        name = lease_name(job.job_id, shard_id)
        builder = get_kernel(job.kernel)
        index = job.shard_index(shard_id)
        space = builder.space.shard(index, job.n_shards)
        if self.evaluator_factory is not None:
            base = self.evaluator_factory(builder, job)
        else:
            base = CostModelEvaluator(builder, job.problem, job.dtype,
                                      get_device(job.device_kind),
                                      verify="none")
        # Every shard evaluation runs through the sandbox: a candidate
        # that hangs/crashes/raises becomes an infeasible result with a
        # ``sandbox:<verdict>`` error — checkpointed like any other
        # evaluation — instead of killing the worker (and stalling the
        # shard a full lease TTL).
        evaluator = SandboxedEvaluator(base, self.sandbox)
        # Resume: a previous (crashed) holder's checkpointed evaluations.
        state = self.bus.fetch("state", name)
        history = [evaluation_from_json(e)
                   for e in (state or {}).get("evaluations", [])]
        log: list[Evaluation] = list(history)
        # Warm start: the scenario's recorded tuning-space dataset, if
        # this worker has one. Only entries *inside* the shard are
        # eligible (off-shard replays would leak measurements across the
        # disjoint shard partition); checkpointed evaluations win on
        # collision (they are this job's own lineage). Dataset history is
        # replayed by the session but not re-published in checkpoints —
        # every peer can read the same dataset itself.
        if self.datasets is not None:
            dataset = self.datasets.load_for(job.kernel, job.device_kind,
                                             job.problem, job.dtype)
            if dataset is not None:
                from repro.tunebench import history_from_dataset
                seen = {space.freeze(e.config) for e in history}
                prior = [e for e in history_from_dataset(dataset, space)
                         if space.freeze(e.config) not in seen]
                history = prior + history
        live = 0

        def checkpoint() -> None:
            # Ownership check (heartbeat raises LeaseLost) BEFORE the
            # state write: a stalled worker whose shard was reclaimed must
            # not clobber the new owner's checkpoints.
            heartbeat(self.bus, lease, self.clock, self.ttl_s)
            self.bus.publish("state", name, {
                "job": job.job_id, "shard": shard_id,
                "worker": self.worker_id,
                "evaluations": [evaluation_to_json(e) for e in log]})

        def evaluate(config: Config) -> EvalResult:
            nonlocal live
            if not space.is_valid(config):
                # outside this shard (or restricted): never measured, so
                # shard result sets stay disjoint across the job
                return EvalResult(INFEASIBLE, False, error="off-shard")
            r = evaluator(config)
            log.append(Evaluation(config=dict(config), score_us=r.score_us,
                                  feasible=r.feasible, wall_s=0.0,
                                  error=r.error))
            live += 1
            self.evals_run += 1
            m = obs.metrics()
            if m is not None:
                m.counter("fleet.shard_evals",
                          worker=self.worker_id).inc()
            if (self.crash_after_evals is not None
                    and live >= self.crash_after_evals):
                self.crash_after_evals = None
                checkpoint()        # the crash loses nothing measured
                raise WorkerCrash(f"{self.worker_id} crashed in {name}")
            if live % self.checkpoint_every == 0:
                checkpoint()
            return r

        result = self._run_strategy(job, shard_id, space, evaluate, history)
        # Ownership check BEFORE the result write (raises LeaseLost). The
        # claim-race safety argument in jobs.claim_shard assumes duplicate
        # shard runs publish identical bytes; dataset warm-starts are
        # per-worker, so a stalled holder's un-warm-started session may
        # have found a *different* (equally valid) result and must not
        # clobber the reclaiming owner's published one.
        heartbeat(self.bus, lease, self.clock, self.ttl_s)
        self._publish_result(job, shard_id, name, result)
        release(self.bus, lease)

    def _run_strategy(self, job: TuningJob, shard_id: str, space, evaluate,
                      history: list[Evaluation]) -> TuningResult:
        if job.strategy not in STRATEGIES:
            raise ValueError(f"job {job.job_id}: unknown strategy "
                             f"{job.strategy!r}; have {sorted(STRATEGIES)}")
        if job.strategy == "exhaustive":
            return STRATEGIES["exhaustive"](space, evaluate,
                                            limit=job.max_evals_per_shard,
                                            history=history)
        rng = np.random.default_rng(job.shard_seed(shard_id))
        return STRATEGIES[job.strategy](space, evaluate,
                                        max_evals=job.max_evals_per_shard,
                                        rng=rng, time_budget_s=None,
                                        history=history)

    def _publish_result(self, job: TuningJob, shard_id: str, name: str,
                        result: TuningResult) -> None:
        self.bus.publish("result", name, {
            "job": job.job_id, "shard": shard_id, "worker": self.worker_id,
            "strategy": result.strategy,
            "evals": len(result.evaluations),
            "feasible_evals": len(result.feasible_evaluations),
            "best_config": result.best_config,
            "best_score_us": (result.best_score_us
                              if result.best_config is not None else None),
        })
