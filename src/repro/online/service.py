"""Online autotuning service — the facade wired into ``WisdomKernel``.

Beyond-paper (closes the loop the paper leaves open between §4.2 capture
and §4.5 selection). The offline flow (capture -> tune out-of-band ->
ship wisdom) cannot cover
scenarios nobody anticipated; they silently run on fuzzy-matched or default
configs forever. ``OnlineTuner`` closes that gap with live traffic:

  launch -> tracker observes the selection tier (miss = tiers 2-5)
         -> hot scenario gets a TrialScheduler (screening + halving bracket)
         -> epsilon fraction of launches run a bracket candidate ("trial")
         -> bracket winner beats incumbent with confidence
         -> PromotionPipeline writes an ``online`` WisdomRecord + hot-swaps

Non-trial launches always run the current incumbent, and all background
work is bounded by the per-launch :class:`OverheadBudget`. Everything is
seeded, so a fixed traffic sequence converges identically run-to-run.

Enable per kernel with :func:`enable_online_tuning`, or globally with
``KERNEL_LAUNCHER_ONLINE=1`` (auto-attached at ``WisdomKernel``
construction). Single-threaded by design: calls happen on the launching
thread, serving stacks with worker pools should attach one tuner per
kernel object.
"""

from __future__ import annotations

import hashlib
import os
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any

import numpy as np

from repro.core.builder import ArgsMeta
from repro.core.device import get_device
from repro.core.param import Config
from repro.core.wisdom_kernel import online_requested  # noqa: F401 (re-export)
from repro.obs import runtime as obs
from repro.tuner.runner import CostModelEvaluator

from .budget import BudgetTimer, OverheadBudget, OverheadMeter
from .promotion import Promotion, PromotionPipeline
from .scheduler import TrialScheduler
from .tracker import MISS_TIERS, ScenarioKey, ScenarioTracker

ONLINE_ENV = "KERNEL_LAUNCHER_ONLINE"
ONLINE_EPSILON_ENV = "KERNEL_LAUNCHER_ONLINE_EPSILON"

DEFAULT_EPSILON = 0.25

#: Wall-clock incumbent timings kept per scenario (rolling window — the
#: incumbent baseline should track recent behaviour, and an observe-only
#: scenario must not accumulate unbounded state in a long-running server).
INCUMBENT_WINDOW = 64


def _scenario_seed(seed: int, kernel: str, key: ScenarioKey) -> int:
    h = hashlib.sha256(f"{seed}|{kernel}|{key}".encode()).digest()
    return int.from_bytes(h[:8], "little")


@dataclass
class _ScenarioState:
    key: ScenarioKey
    scheduler: TrialScheduler
    evaluator: CostModelEvaluator
    rng: np.random.Generator
    meta: ArgsMeta
    incumbent_config: Config
    incumbent_score_us: float | None = None
    incumbent_runs: deque = field(
        default_factory=lambda: deque(maxlen=INCUMBENT_WINDOW))
    pending_trial: Config | None = None
    promotion: Promotion | None = None
    finished: bool = False        # bracket resolved (promoted or kept)
    traced: bool = False          # demand observed only at trace time

    def set_incumbent(self, space, config: Config) -> None:
        """Track the incumbent identity; if selection flipped to a
        different config (e.g. another scenario's promotion changed this
        scenario's fuzzy match), the old baseline timings/score belong to
        the old config and must be discarded."""
        if space.freeze(config) != space.freeze(self.incumbent_config):
            self.incumbent_config = dict(config)
            self.incumbent_runs.clear()
            self.incumbent_score_us = None

    def incumbent_us(self, objective: str) -> float | None:
        if objective == "wallclock":
            if not self.incumbent_runs:
                return None
            return float(np.mean(self.incumbent_runs))
        if self.incumbent_score_us is None:
            r = self.evaluator(self.incumbent_config)
            self.incumbent_score_us = r.score_us
        return self.incumbent_score_us


class OnlineTuner:
    """Traffic-driven tuning for one :class:`WisdomKernel`."""

    def __init__(self, kernel, objective: str = "costmodel",
                 epsilon: float | None = None, seed: int = 0,
                 budget: OverheadBudget | None = None,
                 activation_threshold: int = 3,
                 pool_size: int = 128, bracket_size: int = 8,
                 margin: float = 0.02, min_measurements: int = 1,
                 wisdom_dir: Path | str | None = None,
                 broadcast=None, oracle="auto"):
        if objective not in ("costmodel", "wallclock"):
            raise ValueError(f"unknown objective {objective!r}")
        self.kernel = kernel
        self.objective = objective
        if epsilon is None:
            try:
                epsilon = float(os.environ.get(ONLINE_EPSILON_ENV,
                                               DEFAULT_EPSILON))
            except ValueError as e:
                raise ValueError(
                    f"bad {ONLINE_EPSILON_ENV}: {e}") from None
        self.epsilon = epsilon
        self.seed = seed
        self.budget = budget or OverheadBudget.from_env()
        self.pool_size = pool_size
        self.bracket_size = bracket_size
        self.tracker = ScenarioTracker(activation_threshold)
        self.pipeline = PromotionPipeline(kernel, wisdom_dir=wisdom_dir,
                                          margin=margin,
                                          min_measurements=min_measurements,
                                          broadcast=broadcast,
                                          oracle=oracle)
        self.meter = OverheadMeter()
        self.events: list[tuple[str, ScenarioKey, Any]] = []
        self._states: dict[ScenarioKey, _ScenarioState] = {}

    # -- WisdomKernel hooks ----------------------------------------------------

    def before_launch(self, problem: tuple[int, ...], dtype: str,
                      meta: ArgsMeta, config: Config,
                      tier: str) -> Config | None:
        """Observe a selection; return a candidate config to divert this
        launch into a trial, or None to launch the incumbent untouched."""
        self.meter.begin()
        try:
            st = self.tracker.observe(self.kernel.device_kind, problem,
                                      dtype, tier)
            state = self._states.get(st.key)
            if state is None:
                if not self.tracker.is_hot(*st.key):
                    return None
                state = self._activate(st.key, meta, config)
            if state.finished:
                return None
            state.traced = False          # scenario has eager traffic now
            state.set_incumbent(self.kernel.builder.space, config)
            cand = state.scheduler.next_trial()
            if cand is None:
                return None
            if state.rng.random() >= self.epsilon:
                return None
            state.pending_trial = cand
            st.trials += 1
            m = obs.metrics()
            if m is not None:
                m.counter("online.trials",
                          kernel=self.kernel.builder.name).inc()
            return cand
        finally:
            self.meter.end()

    def after_launch(self, problem: tuple[int, ...], dtype: str,
                     config: Config, tier: str, launch_s: float) -> None:
        """Account the finished launch, then spend this launch's overhead
        budget on background tuning work."""
        self.meter.begin()
        key = self.tracker.key(self.kernel.device_kind, problem, dtype)
        state = self._states.get(key)
        screens = 0
        trial = tier == "trial"
        if state is not None and not state.finished:
            if trial:
                score = self._trial_score(state, config, launch_s)
                state.scheduler.report_trial(config, score)
                state.pending_trial = None
            elif tier != "forced":
                state.incumbent_runs.append(launch_s * 1e6)
            timer = BudgetTimer(self.budget)
            screens = state.scheduler.screen(timer)
            self._maybe_promote(state)
        before_s = self.meter.overhead_s
        self.meter.end(screens=screens, trial=trial, launch=True)
        self._observe_spend(self.meter.overhead_s - before_s, screens)

    def observe_traced(self, problem: tuple[int, ...], dtype: str,
                       meta: ArgsMeta, config: Config, tier: str) -> None:
        """Record a trace-time selection (launch running inside an outer
        jit). One trace stands for a whole execution stream, so a missed
        traced selection makes the scenario hot immediately; the actual
        tuning work then runs through :meth:`tick` (the host's decode/train
        loop sponsors it), not through launch hooks."""
        st = self.tracker.observe(self.kernel.device_kind, problem, dtype,
                                  tier,
                                  weight=self.tracker.activation_threshold)
        state = self._states.get(st.key)
        if state is None and tier in MISS_TIERS:
            state = self._activate(st.key, meta, config)
            state.traced = True
        elif state is not None and not state.finished:
            state.set_incumbent(self.kernel.builder.space, config)

    # -- background work without launches -------------------------------------

    def tick(self) -> int:
        """Advance screening/promotion for every active scenario under one
        launch's worth of budget — for hosts (serving decode loop, train
        warmup) that want tuning progress between kernel launches.

        Scenarios whose demand was observed only at trace time (launches
        running inside an outer jit) can never receive live trial
        measurements; under the deterministic cost-model objective their
        bracket is resolved here instead, with evaluator scores — exactly
        what a live trial would have reported. (Under the wall-clock
        objective traced scenarios stop at screening: there is nothing to
        measure.) A promotion then lands in the wisdom file for the next
        trace/restart to select."""
        self.meter.begin()
        screens = 0
        timer = BudgetTimer(self.budget)
        for state in self._states.values():
            if state.finished:
                continue
            screens += state.scheduler.screen(timer)
            if state.traced and self.objective == "costmodel":
                while timer.take():
                    cand = state.scheduler.next_trial()
                    if cand is None:
                        break
                    state.scheduler.report_trial(
                        cand, state.evaluator(cand).score_us)
                    screens += 1
            self._maybe_promote(state)
        before_s = self.meter.overhead_s
        self.meter.end(screens=screens)
        self._observe_spend(self.meter.overhead_s - before_s, screens)
        return screens

    # -- internals -------------------------------------------------------------

    def _observe_spend(self, spent_s: float, screens: int) -> None:
        """Report this slice of background-tuning budget to telemetry."""
        m = obs.metrics()
        if m is None:
            return
        name = self.kernel.builder.name
        m.counter("online.overhead_us", kernel=name).inc(spent_s * 1e6)
        if screens:
            m.counter("online.screens", kernel=name).inc(screens)

    def _activate(self, key: ScenarioKey, meta: ArgsMeta,
                  incumbent: Config) -> _ScenarioState:
        device_kind, problem, dtype = key
        rng = np.random.default_rng(
            _scenario_seed(self.seed, self.kernel.builder.name, key))
        evaluator = CostModelEvaluator(self.kernel.builder, problem, dtype,
                                       get_device(device_kind),
                                       verify="none")
        state = _ScenarioState(
            key=key,
            scheduler=TrialScheduler(self.kernel.builder.space, evaluator,
                                     rng, pool_size=self.pool_size,
                                     bracket_size=self.bracket_size),
            evaluator=evaluator, rng=rng, meta=meta,
            incumbent_config=dict(incumbent))
        self._states[key] = state
        self.events.append(("activate", key, dict(incumbent)))
        return state

    def _trial_score(self, state: _ScenarioState, config: Config,
                     launch_s: float) -> float:
        if self.objective == "wallclock":
            return launch_s * 1e6
        return state.evaluator(config).score_us

    def _promotion_outcome(self, state: _ScenarioState,
                           outcome: str) -> None:
        m = obs.metrics()
        if m is not None:
            m.counter("online.promotions",
                      kernel=self.kernel.builder.name,
                      outcome=outcome).inc()
        tr = obs.tracer()
        if tr is not None:
            from repro.core.scenario import format_key
            tr.instant("online." + outcome, cat="online",
                       kernel=self.kernel.builder.name,
                       scenario=format_key(state.key))

    def _maybe_promote(self, state: _ScenarioState) -> None:
        if state.scheduler.bracket_dead:
            # screening found nothing feasible: stop spending on this
            # scenario, the incumbent is all there is
            state.finished = True
            self.events.append(("no-candidates", state.key,
                                dict(state.incumbent_config)))
            self._promotion_outcome(state, "no-candidates")
            return
        won = state.scheduler.winner()
        if won is None:
            return
        config, score_us, n_meas = won
        incumbent_us = state.incumbent_us(self.objective)
        if incumbent_us is None:
            return          # wallclock objective, incumbent not yet timed
        device_kind, problem, dtype = state.key
        rejections_before = len(self.pipeline.rejections)
        promo = self.pipeline.promote(
            device_kind, problem, dtype, config, score_us, incumbent_us,
            n_measurements=n_meas, evals=state.scheduler.screens + n_meas,
            objective=self.objective,
            meta=None if state.traced else state.meta)
        state.finished = True
        if promo is not None:
            state.promotion = promo
            self.events.append(("promote", state.key, promo))
            self._promotion_outcome(state, "promoted")
        elif len(self.pipeline.rejections) > rejections_before:
            # the winner beat the incumbent but failed the correctness
            # oracle — the incumbent keeps serving, and the veto is an
            # event of its own so dashboards can tell it from "not faster"
            rej = self.pipeline.rejections[-1]
            self.events.append(("oracle-reject", state.key, rej))
            self._promotion_outcome(state, "rejected")
        else:
            self.events.append(("keep-incumbent", state.key,
                                dict(state.incumbent_config)))
            self._promotion_outcome(state, "kept")

    # -- introspection ---------------------------------------------------------

    def state(self, problem: tuple[int, ...],
              dtype: str) -> _ScenarioState | None:
        return self._states.get(
            self.tracker.key(self.kernel.device_kind, problem, dtype))

    def promotions(self) -> list[Promotion]:
        return list(self.pipeline.promotions)

    def status(self) -> dict:
        return {
            "kernel": self.kernel.builder.name,
            "objective": self.objective,
            "epsilon": self.epsilon,
            "scenarios": len(self.tracker),
            "active": sum(1 for s in self._states.values()
                          if not s.finished),
            "promotions": len(self.pipeline.promotions),
            "broadcasts": self.pipeline.broadcasts,
            "launches": self.meter.launches,
            "trials": self.meter.trials,
            "screens": self.meter.screens,
            "overhead_per_launch_s": self.meter.overhead_per_launch_s,
        }


def enable_online_tuning(kernel, **kwargs) -> OnlineTuner:
    """Construct an :class:`OnlineTuner` for ``kernel`` and attach it."""
    tuner = OnlineTuner(kernel, **kwargs)
    kernel.attach_online(tuner)
    return tuner
