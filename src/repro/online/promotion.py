"""Promotion pipeline: bracket winner -> wisdom record -> hot swap.

Beyond-paper (writes standard §4.4 wisdom records, so offline tooling
and the fleet merge engine treat promotions like any tuning session).
Once a scenario's successive-halving bracket has a winner, the pipeline
decides whether it is confidently better than the incumbent (relative margin
over the incumbent's score, plus a minimum number of live measurements),
and if so:

1. writes a fresh :class:`~repro.core.wisdom.WisdomRecord` through
   ``core/wisdom.py`` with ``online`` provenance (``strategy="online"`` and
   an ``online: true`` marker, so offline re-tuning can tell the two
   apart and the usual keep-best re-tune semantics apply);
2. *prewarms* the winning variant in the kernel's compile cache so the hot
   swap never stalls a live launch on compilation;
3. refreshes the kernel's wisdom + selection caches (without dropping
   compiled executables) so the very next launch of the scenario selects
   the promoted record at tier "exact";
4. optionally *broadcasts* the record to the fleet through a
   ``repro.distrib`` push hook (beyond-paper: §4.4 wisdom as a fleet
   asset), so other hosts learn the winner without re-tuning. Broadcast
   failures are swallowed — fleet distribution is best-effort, the local
   write is the source of truth.

Between the confidence decision and the wisdom write sits the mandatory
correctness gate (:class:`repro.sandbox.gate.OracleGate`): the winning
config is executed against the kernel's reference oracle on synthesized
probe arguments, and a ``numerics-mismatch``/``crash`` verdict vetoes
the promotion (recorded on :attr:`PromotionPipeline.rejections`) — a
fast-but-wrong candidate can win a bracket, but it cannot become
serving wisdom. Passing configs get ``verified`` provenance.
"""

from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

from repro.core.builder import ArgsMeta
from repro.core.device import get_device
from repro.core.wisdom import Wisdom, WisdomRecord, make_provenance
from repro.sandbox.gate import OracleGate
from repro.sandbox.verdict import SandboxVerdict

DEFAULT_MARGIN = 0.02
DEFAULT_MIN_MEASUREMENTS = 1


@dataclass
class Promotion:
    """Outcome of a successful promotion, for logs/benchmarks."""
    record: WisdomRecord
    incumbent_score_us: float
    improvement: float           # fractional, e.g. 0.31 = 31% faster


@dataclass
class Rejection:
    """A bracket winner the correctness oracle vetoed."""
    key: tuple                   # (device_kind, problem, dtype)
    config: dict
    verdict: SandboxVerdict


class PromotionPipeline:
    def __init__(self, kernel, wisdom_dir: Path | str | None = None,
                 margin: float = DEFAULT_MARGIN,
                 min_measurements: int = DEFAULT_MIN_MEASUREMENTS,
                 broadcast=None, oracle="auto"):
        self.kernel = kernel                       # WisdomKernel
        self.wisdom_dir = (wisdom_dir if wisdom_dir is not None
                           else kernel.wisdom_dir)
        self.margin = margin
        self.min_measurements = min_measurements
        #: Fleet hook: a ``repro.distrib.PushSync`` (or any object with
        #: ``broadcast(kernel_name, record)``), or a bare callable taking
        #: the same two arguments. None = local-only (the paper's model).
        self.broadcast = broadcast
        self.broadcasts = 0
        #: The correctness gate every winner must clear before the wisdom
        #: write. ``"auto"`` = a default :class:`OracleGate` (verify when
        #: the kernel has probe/reference hooks, allow when it does not);
        #: None disables gating (tests only — promotions then skip
        #: verification entirely).
        self.oracle = OracleGate() if oracle == "auto" else oracle
        self.promotions: list[Promotion] = []
        #: Winners vetoed by the oracle, in veto order.
        self.rejections: list[Rejection] = []

    def _broadcast(self, record: WisdomRecord) -> None:
        if self.broadcast is None:
            return
        fn = getattr(self.broadcast, "broadcast", self.broadcast)
        try:
            fn(self.kernel.builder.name, record)
            self.broadcasts += 1
        except Exception:  # pragma: no cover — never break serving
            pass

    def confident(self, winner_score_us: float, incumbent_score_us: float,
                  n_measurements: int) -> bool:
        if n_measurements < self.min_measurements:
            return False
        return winner_score_us < incumbent_score_us * (1.0 - self.margin)

    def promote(self, device_kind: str, problem: tuple[int, ...], dtype: str,
                config: dict, score_us: float, incumbent_score_us: float,
                n_measurements: int, evals: int, objective: str,
                meta: ArgsMeta | None = None) -> Promotion | None:
        """Write + hot-swap if confident; returns the Promotion or None."""
        if not self.confident(score_us, incumbent_score_us, n_measurements):
            return None
        verdict = None
        if self.oracle is not None:
            verdict = self.oracle.check(self.kernel.builder, config,
                                        problem, dtype)
            if not self.oracle.allows(verdict):
                self.rejections.append(Rejection(
                    key=(device_kind, tuple(int(x) for x in problem),
                         dtype),
                    config=dict(config), verdict=verdict))
                return None
        dev = get_device(device_kind)
        provenance = make_provenance(strategy="online", evals=evals,
                                     objective=objective)
        provenance["online"] = True
        provenance["live_measurements"] = n_measurements
        if verdict is not None:
            provenance = self.oracle.stamp(
                provenance, self.kernel.builder.name, verdict)
        record = WisdomRecord(
            device_kind=dev.kind, device_family=dev.family,
            problem_size=tuple(int(x) for x in problem), dtype=dtype,
            config=dict(config), score_us=float(score_us),
            provenance=provenance)
        wisdom = Wisdom.load(self.kernel.builder.name, self.wisdom_dir)
        wisdom.add(record)
        wisdom.save(self.wisdom_dir)
        self._broadcast(record)

        # Hot swap: compile the winner first, then flip selection to it.
        if meta is not None:
            try:
                self.kernel.prewarm(meta, record.config)
            except Exception:  # pragma: no cover — never break serving
                pass
        self.kernel.refresh_wisdom()

        promo = Promotion(
            record=record, incumbent_score_us=incumbent_score_us,
            improvement=1.0 - score_us / max(incumbent_score_us, 1e-12))
        self.promotions.append(promo)
        return promo
