"""Scenario tracker: turn live launch telemetry into tuning demand.

Beyond-paper (consumes the §4.5 selection tiers; the paper has no
runtime feedback loop). Every non-traced ``WisdomKernel`` launch reports its scenario (device kind,
problem size, dtype) and the §4.5 selection tier it resolved to. Tiers below
"exact" mean the wisdom file had no record tuned for this exact scenario —
the launch ran on a fuzzy-matched or default configuration. The tracker
accumulates those misses per scenario and flags a scenario *hot* once its
miss count crosses the activation threshold, which is the signal for the
trial scheduler to start spending budget on it.

Traffic-driven by construction: a scenario nobody launches never gets
tuned, and the busiest untuned scenario becomes hot first.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# Canonical scenario-key and tier vocabulary. Defined once in
# core/scenario.py (shared with Wisdom.select and the observability
# report); re-exported here because the fleet/demand/transfer layers
# have always imported them from this module.
from repro.core.scenario import (HIT_TIERS, MISS_TIERS,  # noqa: F401
                                 SELECT_TIERS, ScenarioKey, format_key,
                                 parse_key)


@dataclass
class ScenarioStats:
    key: ScenarioKey
    launches: int = 0          # observed non-traced launches
    misses: int = 0            # launches that fell through to tiers 2-5
    trials: int = 0            # launches diverted to candidate configs
    last_tier: str = ""
    tiers: dict[str, int] = field(default_factory=dict)

    @property
    def device_kind(self) -> str:
        return self.key[0]

    @property
    def problem(self) -> tuple[int, ...]:
        return self.key[1]

    @property
    def dtype(self) -> str:
        return self.key[2]

    def to_json(self) -> dict:
        return {"key": format_key(self.key), "launches": self.launches,
                "misses": self.misses, "trials": self.trials,
                "last_tier": self.last_tier, "tiers": dict(self.tiers)}

    @staticmethod
    def from_json(d: dict) -> "ScenarioStats":
        return ScenarioStats(key=parse_key(d["key"]),
                             launches=int(d.get("launches", 0)),
                             misses=int(d.get("misses", 0)),
                             trials=int(d.get("trials", 0)),
                             last_tier=str(d.get("last_tier", "")),
                             tiers={str(k): int(v)
                                    for k, v in d.get("tiers", {}).items()})


class ScenarioTracker:
    """Per-scenario launch/miss accounting with an activation threshold."""

    def __init__(self, activation_threshold: int = 3):
        self.activation_threshold = activation_threshold
        self._stats: dict[ScenarioKey, ScenarioStats] = {}

    @staticmethod
    def key(device_kind: str, problem: tuple[int, ...],
            dtype: str) -> ScenarioKey:
        return (device_kind, tuple(int(x) for x in problem), str(dtype))

    def observe(self, device_kind: str, problem: tuple[int, ...], dtype: str,
                tier: str, weight: int = 1) -> ScenarioStats:
        """Record one selection. ``weight`` scales the demand: a trace-time
        selection stands for a whole compiled execution stream, not one
        launch, so traced observations pass ``weight=activation_threshold``
        to make the scenario hot immediately."""
        k = self.key(device_kind, problem, dtype)
        st = self._stats.get(k)
        if st is None:
            st = self._stats[k] = ScenarioStats(key=k)
        st.launches += 1
        st.last_tier = tier
        st.tiers[tier] = st.tiers.get(tier, 0) + 1
        if tier in MISS_TIERS:
            st.misses += weight
        return st

    def is_hot(self, device_kind: str, problem: tuple[int, ...],
               dtype: str) -> bool:
        st = self._stats.get(self.key(device_kind, problem, dtype))
        return st is not None and st.misses >= self.activation_threshold

    def stats(self, device_kind: str, problem: tuple[int, ...],
              dtype: str) -> ScenarioStats | None:
        return self._stats.get(self.key(device_kind, problem, dtype))

    def hot_scenarios(self) -> list[ScenarioStats]:
        """Hot scenarios, busiest first (tuning priority order)."""
        hot = [s for s in self._stats.values()
               if s.misses >= self.activation_threshold]
        return sorted(hot, key=lambda s: -s.misses)

    def all_scenarios(self) -> list[ScenarioStats]:
        return list(self._stats.values())

    def snapshot(self) -> list[dict]:
        """JSON-safe demand snapshot, canonically keyed and ordered — what
        a fleet worker publishes through a sync transport."""
        return [self._stats[k].to_json()
                for k in sorted(self._stats, key=format_key)]

    def __len__(self) -> int:
        return len(self._stats)
