"""Trial scheduler: budgeted candidate search for one hot scenario.

Beyond-paper (the online analogue of the §4.3 search strategies). Two
channels, matching the ``CostModelEvaluator`` / ``WallClockEvaluator``
split in the offline tuner:

* **Screening** (background, charged to the per-launch overhead budget):
  candidate configurations stream out of the ``ConfigSpace`` — a shuffled
  exhaustive enumeration when the space is small, seeded rejection sampling
  otherwise — and are scored with the analytical cost model through a
  ``tuner.strategies._Session`` (same dedup / best-so-far / exhaustion
  bookkeeping the offline strategies use). A few screenings run per launch,
  never more than the budget allows.

* **Live trials** (epsilon-greedy, a small fraction of real launches): the
  top screened candidates enter a successive-halving bracket. Each trial
  launch executes one bracket member's config instead of the incumbent and
  reports a measurement back; when every surviving member has its rung's
  quota of measurements, the worse half is eliminated and the quota doubles.
  The last survivor is the promotion candidate.

With the deterministic cost-model objective one measurement per member is
enough and the bracket degenerates to top-1 selection; with wall-clock
measurements the halving structure is what gives noisy candidates a fair,
budget-bounded comparison (successive halving per Schoonhoven et al.'s
budget-constrained search comparison; dynamic-tuning shape per Petrovič et
al.'s KTT).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np

from repro.core.param import Config, ConfigSpace
from repro.tuner.strategies import Evaluate, _Session

from .budget import BudgetTimer

#: Enumerate-and-shuffle (full coverage) below this many raw configs;
#: sample above it.
ENUMERATE_LIMIT = 1024


@dataclass
class _Member:
    config: Config
    key: tuple
    screen_score_us: float
    measurements: list[float] = field(default_factory=list)

    def mean(self) -> float:
        if not self.measurements:
            return self.screen_score_us
        return float(np.mean(self.measurements))


class _Bracket:
    """Successive halving over an ordered candidate list."""

    def __init__(self, members: list[_Member], eta: int = 2, r0: int = 1):
        self.members = members
        self.eta = max(eta, 2)
        self.rung = 0
        self.r0 = max(r0, 1)

    @property
    def quota(self) -> int:
        """Total measurements each survivor needs at the current rung."""
        return self.r0 * self.eta ** self.rung

    @property
    def done(self) -> bool:
        return (len(self.members) == 1
                and len(self.members[0].measurements) >= self.quota)

    def next_trial(self) -> _Member | None:
        if self.done:
            return None
        for m in self.members:
            if len(m.measurements) < self.quota:
                return m
        return None

    def report(self, key: tuple, score_us: float) -> None:
        for m in self.members:
            if m.key == key and len(m.measurements) < self.quota:
                m.measurements.append(score_us)
                break
        self._maybe_advance()

    def _maybe_advance(self) -> None:
        if len(self.members) <= 1:
            return
        if any(len(m.measurements) < self.quota for m in self.members):
            return
        keep = max(1, math.ceil(len(self.members) / self.eta))
        self.members.sort(key=lambda m: (m.mean(), m.screen_score_us))
        self.members = self.members[:keep]
        self.rung += 1

    def winner(self) -> _Member | None:
        return self.members[0] if self.done else None


class TrialScheduler:
    """Candidate search for one scenario, driven in budgeted increments."""

    def __init__(self, space: ConfigSpace, evaluate: Evaluate,
                 rng: np.random.Generator, pool_size: int = 128,
                 bracket_size: int = 8, eta: int = 2, r0: int = 1):
        self.space = space
        self.rng = rng
        self.pool_size = pool_size
        self.bracket_size = bracket_size
        self.eta = eta
        self.r0 = r0
        self.session = _Session(space, evaluate, max_evals=pool_size,
                                time_budget_s=None)
        self._stream = self._candidate_stream()
        self._stream_done = False
        self._bracket: _Bracket | None = None

    # -- screening channel ---------------------------------------------------

    def _candidate_stream(self) -> Iterator[Config]:
        yield self.space.default_config()
        if self.space.cardinality() <= ENUMERATE_LIMIT:
            cfgs = list(self.space.enumerate())
            self.rng.shuffle(cfgs)
            yield from cfgs
        else:
            while True:
                yield self.space.sample(self.rng, 1)[0]

    def screen(self, timer: BudgetTimer) -> int:
        """Run cost-model screenings until the timer or the pool runs out.
        Returns the number of evaluations performed."""
        done = 0
        while not self.screening_done() and timer.take():
            cfg = next(self._stream, None)
            if cfg is None:
                self._stream_done = True
                break
            self.session.run(cfg)
            done += 1
        if self.screening_done() and self._bracket is None:
            self._build_bracket()
        return done

    def screening_done(self) -> bool:
        return self._stream_done or self.session.exhausted()

    def _build_bracket(self) -> None:
        feasible = sorted(self.session.feasible(),
                          key=lambda e: e.score_us)[:self.bracket_size]
        members = [_Member(config=dict(e.config),
                           key=self.space.freeze(e.config),
                           screen_score_us=e.score_us)
                   for e in feasible]
        self._bracket = _Bracket(members, eta=self.eta, r0=self.r0)

    # -- live-trial channel --------------------------------------------------

    def next_trial(self) -> Config | None:
        """Config the next trial launch should run, or None if no live
        measurement is currently needed."""
        if self._bracket is None:
            return None
        m = self._bracket.next_trial()
        return dict(m.config) if m is not None else None

    def report_trial(self, config: Config, score_us: float) -> None:
        if self._bracket is not None:
            self._bracket.report(self.space.freeze(config), score_us)

    def winner(self) -> tuple[Config, float, int] | None:
        """(config, mean score, n live measurements) of the last survivor."""
        if self._bracket is None:
            return None
        m = self._bracket.winner()
        if m is None:
            return None
        return dict(m.config), m.mean(), len(m.measurements)

    @property
    def bracket_dead(self) -> bool:
        """Screening finished but produced no feasible candidates — there
        is nothing to trial and never will be."""
        return self._bracket is not None and not self._bracket.members

    # -- introspection -------------------------------------------------------

    @property
    def screens(self) -> int:
        return len(self.session.evals)

    def best_screened(self) -> tuple[Config, float] | None:
        if self.session.best is None:
            return None
        return dict(self.session.best.config), self.session.best.score_us
