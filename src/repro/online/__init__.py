"""Online autotuning: traffic-driven tuning with live wisdom promotion.

Beyond-paper subsystem (builds on §4.4 wisdom files and the §4.5
selection heuristic). The paper's workflow is strictly offline — capture a launch, tune it
out-of-band, ship the wisdom file (§4.2-§4.4). Any scenario not tuned ahead
of time falls through the §4.5 selection heuristic to a fuzzy match or the
default config, forever. This subsystem turns those wisdom *misses* into
background tuning work driven by the traffic itself:

* :mod:`.tracker`   — detects misses, accumulates per-scenario demand;
* :mod:`.budget`    — hard per-launch overhead budget for background work;
* :mod:`.scheduler` — budgeted cost-model screening + successive-halving
  live trials (epsilon-greedy over real launches);
* :mod:`.promotion` — confident winners become ``online``-provenance
  wisdom records, hot-swapped without a compile stall;
* :mod:`.service`   — the :class:`OnlineTuner` facade ``WisdomKernel``
  calls into, plus ``KERNEL_LAUNCHER_ONLINE`` auto-attach support.

Prefer offline ``tuner.tune`` when you can enumerate scenarios ahead of
time (bigger budgets, no serving-path overhead at all); enable online
tuning when the scenario set is open-ended and wisdom must follow traffic.
"""

from .budget import (BudgetTimer, OverheadBudget, OverheadMeter,
                     ONLINE_BUDGET_MS_ENV, ONLINE_SCREENS_ENV)
from .promotion import Promotion, PromotionPipeline
from .scheduler import TrialScheduler
from .service import (OnlineTuner, enable_online_tuning, online_requested,
                      ONLINE_ENV, ONLINE_EPSILON_ENV)
from .tracker import (MISS_TIERS, ScenarioStats, ScenarioTracker,
                      ScenarioKey, format_key, parse_key)

__all__ = [
    "BudgetTimer", "OverheadBudget", "OverheadMeter",
    "ONLINE_BUDGET_MS_ENV", "ONLINE_SCREENS_ENV",
    "Promotion", "PromotionPipeline",
    "TrialScheduler",
    "OnlineTuner", "enable_online_tuning", "online_requested",
    "ONLINE_ENV", "ONLINE_EPSILON_ENV",
    "MISS_TIERS", "ScenarioStats", "ScenarioTracker", "ScenarioKey",
    "format_key", "parse_key",
]
