"""Per-launch overhead budget for the online autotuning service.

Beyond-paper (the paper's §4.3 tuning runs out-of-band with a 15-minute
budget; online work rides the serving path, so the budget is per launch
and three orders of magnitude smaller). Online tuning must never turn a
serving hot path into a tuning session: all
background work the service does on behalf of one launch (cost-model
screening, bracket bookkeeping, promotion checks) is bounded by a *hard*
wall-clock budget per launch plus a deterministic cap on the number of
cost-model screenings. The wall-clock bound is the safety net on slow hosts;
the screening cap is what makes convergence tests reproducible (a pure time
budget would admit a host-speed-dependent amount of work).

Env vars:

  KERNEL_LAUNCHER_ONLINE_BUDGET_MS   per-launch overhead budget in
                                     milliseconds (default 2.0)
  KERNEL_LAUNCHER_ONLINE_SCREENS     max cost-model screenings charged to
                                     one launch (default 8)
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

ONLINE_BUDGET_MS_ENV = "KERNEL_LAUNCHER_ONLINE_BUDGET_MS"
ONLINE_SCREENS_ENV = "KERNEL_LAUNCHER_ONLINE_SCREENS"

DEFAULT_BUDGET_MS = 2.0
DEFAULT_SCREENS_PER_LAUNCH = 8


@dataclass(frozen=True)
class OverheadBudget:
    """Static budget policy: how much overhead one launch may sponsor."""

    per_launch_s: float = DEFAULT_BUDGET_MS * 1e-3
    screens_per_launch: int = DEFAULT_SCREENS_PER_LAUNCH

    @staticmethod
    def from_env() -> "OverheadBudget":
        try:
            ms = float(os.environ.get(ONLINE_BUDGET_MS_ENV,
                                      DEFAULT_BUDGET_MS))
        except ValueError as e:
            raise ValueError(f"bad {ONLINE_BUDGET_MS_ENV}: {e}") from None
        try:
            screens = int(os.environ.get(ONLINE_SCREENS_ENV,
                                         DEFAULT_SCREENS_PER_LAUNCH))
        except ValueError as e:
            raise ValueError(f"bad {ONLINE_SCREENS_ENV}: {e}") from None
        return OverheadBudget(per_launch_s=ms * 1e-3,
                              screens_per_launch=screens)


class BudgetTimer:
    """One launch's slice of background work: a deadline + an op counter.

    ``take()`` consumes one screening slot; it returns False as soon as
    either the wall-clock deadline or the op cap is reached, after which the
    caller must stop doing work for this launch.
    """

    def __init__(self, budget: OverheadBudget):
        self._deadline = time.perf_counter() + budget.per_launch_s
        self._ops_left = budget.screens_per_launch
        self.ops_taken = 0

    def take(self) -> bool:
        if self._ops_left <= 0 or time.perf_counter() >= self._deadline:
            return False
        self._ops_left -= 1
        self.ops_taken += 1
        return True


@dataclass
class OverheadMeter:
    """Running totals of what the online service actually spent."""

    launches: int = 0
    trials: int = 0
    screens: int = 0
    overhead_s: float = 0.0
    _t0: float = field(default=0.0, repr=False)

    def begin(self) -> None:
        self._t0 = time.perf_counter()

    def end(self, screens: int = 0, trial: bool = False,
            launch: bool = False) -> None:
        self.overhead_s += time.perf_counter() - self._t0
        self.launches += int(launch)
        self.screens += screens
        self.trials += int(trial)

    @property
    def overhead_per_launch_s(self) -> float:
        return self.overhead_s / self.launches if self.launches else 0.0
