"""Serving engine: batched decode over a slot arena — token-level
continuous batching by default, lock-step cohorts as the fallback.

Two scheduling modes over the same static (n_slots, max_seq) KV arena
(see docs/serving.md for the slot lifecycle):

* **token** (default whenever the model's ``decode_supports_start`` says
  per-slot attention windows work): the arena keeps one physical write
  cursor (``cache["pos"]``) but each slot owns a logical window
  ``[start[b], pos]`` carried in ``cache["start"]``. A request that
  finishes frees its slot *mid-stream*; the next queued request — picked
  from the batcher's scenario buckets so concurrent slots share a tuned
  scenario — is admitted at the current cursor and fed its prompt
  per-slot while other slots keep generating. When the arena runs out,
  the engine opens a fresh arena generation (new cache) and continues.
  Stale K/V from a slot's previous occupant sits below ``start`` and is
  masked out of attention entirely (zeroing would still leak softmax
  weight), which also keeps rotary phases correct: only relative
  distances within a slot's own window survive the mask.

* **cohort** (fallback for recurrent mixers, MLA, cross-attention and
  learned-position models — their decode state cannot be scoped to a
  slot window by masking — and the A/B baseline for benchmarks): admit a
  cohort into free slots and run lock-step until every member finishes;
  every cohort stalls on its slowest member, which is exactly the
  occupancy loss ``benchmarks/serve_throughput.py`` measures.

Greedy (argmax) or temperature sampling.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import runtime as obs
from repro.obs.metrics import COUNT_BUCKETS, UNIT_BUCKETS

from .batching import ContinuousBatcher


@dataclass
class Request:
    """One generation request: prompt tokens in, sampled tokens out.

    ``scenario`` is an optional tuned-scenario key (``core/scenario.py``
    ``format_key`` string, e.g. ``"tpu-v5e|256x256|float32"``): the
    batcher buckets admission by it so slots running concurrently share
    a wisdom-exact configuration. Empty string = unbucketed."""
    request_id: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    scenario: str = ""
    tokens: list = field(default_factory=list)   # generated


@dataclass
class ServeReport:
    """What one :meth:`ServeEngine.run` call did.

    Mapping-compatible with the historical ``{request_id: tokens}``
    return value (``report[rid]``, iteration, ``len``, ``in`` all
    delegate to :attr:`outputs`), so existing callers keep working while
    new ones read the run stats directly. ``cohorts`` counts lock-step
    cohorts in cohort mode and arena generations in token mode;
    ``occupancy`` is the fraction of slot-steps that advanced a live
    request (the number token-level scheduling exists to raise);
    ``inflight_admissions`` counts requests admitted while other slots
    were mid-generation — always 0 in cohort mode.
    """

    outputs: dict[int, list[int]]
    cohorts: int = 0
    requests_completed: int = 0
    steps: int = 0
    sync_pulls: int = 0
    sync_failures: int = 0
    mode: str = "cohort"
    occupancy: float = 0.0
    inflight_admissions: int = 0
    scenario_switches: int = 0

    def __getitem__(self, request_id: int) -> list[int]:
        return self.outputs[request_id]

    def __iter__(self):
        return iter(self.outputs)

    def __len__(self) -> int:
        return len(self.outputs)

    def __contains__(self, request_id: int) -> bool:
        return request_id in self.outputs

    def keys(self):
        return self.outputs.keys()

    def values(self):
        return self.outputs.values()

    def items(self):
        return self.outputs.items()

    def to_json(self) -> dict:
        return {"cohorts": self.cohorts,
                "requests_completed": self.requests_completed,
                "steps": self.steps, "sync_pulls": self.sync_pulls,
                "sync_failures": self.sync_failures, "mode": self.mode,
                "occupancy": self.occupancy,
                "inflight_admissions": self.inflight_admissions,
                "scenario_switches": self.scenario_switches}


class ServeEngine:
    """Continuous-batching LM server over a static KV arena.

    Submit :class:`Request` objects, then :meth:`run` to completion; the
    returned :class:`ServeReport` maps request ids to generated tokens
    plus run statistics. ``mode`` is ``"auto"`` (token-level when the
    model supports per-slot attention windows, else cohort), ``"token"``
    or ``"cohort"``. Optional collaborators: online autotuners
    (``repro.online``), fleet wisdom sync (``repro.distrib.PullSync``)
    and a decode-step roofline profiler (``repro.prof``) all tick once
    per decode step in either mode.

    Example::

        eng = ServeEngine(model, params, n_slots=4, max_seq=256)
        eng.submit(Request(0, np.array([1, 2, 3]), max_new_tokens=8))
        report = eng.run()
        report[0]          # -> 8 generated token ids
    """

    def __init__(self, model, params, n_slots: int = 4,
                 max_seq: int = 512, temperature: float = 0.0,
                 rng_seed: int = 0, online=None, sync=None,
                 profiler=None, mode: str = "auto"):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        if mode not in ("auto", "token", "cohort"):
            raise ValueError(f"unknown serve mode {mode!r} "
                             f"(want auto|token|cohort)")
        if mode == "auto":
            mode = ("token"
                    if getattr(model, "decode_supports_start", False)
                    else "cohort")
        self.mode = mode
        self.batcher = ContinuousBatcher(n_slots, max_seq)
        self._decode = jax.jit(model.decode_step)
        self._requests: dict[int, Request] = {}
        self._rng = np.random.default_rng(rng_seed)
        self.steps_run = 0
        self._useful_slot_steps = 0
        self._inflight_admissions = 0
        # Optional online autotuner(s) (repro.online.OnlineTuner): each
        # decode step sponsors one launch-budget slice of background tuning
        # via tick(). Kernels launched inside the jitted decode report
        # their scenario at trace time (observe_traced); tick() screens
        # them and — under the cost-model objective — resolves their
        # bracket too, since live trials can't be interleaved into a
        # compiled graph. Promotions land in wisdom for the next trace.
        if online is None:
            online = []
        elif not isinstance(online, (list, tuple)):
            online = [online]
        self.online = list(online)
        # Optional fleet wisdom pull (repro.distrib.PullSync): tick() is
        # called once per decode step and actually pulls every
        # sync.interval ticks, merging fleet wisdom into the local store
        # and hot-refreshing attached kernels — this host serves with the
        # whole fleet's tuning results, not just its own.
        self.sync = sync
        # Optional decode-step profiler (repro.prof.StepProfiler): every
        # Nth step is timed to a blocking boundary and recorded as a
        # "serve.decode" roofline profile (params streamed from HBM per
        # step → small-batch decode is memory-bound; the profile says by
        # how much, and drifts against the run's first sampled step).
        # Unsampled steps pay one None check — no extra block/clock.
        self.profiler = profiler
        if profiler is None:
            from repro.prof.profiler import (StepProfiler,
                                             process_profiler)
            ambient = process_profiler()
            if ambient is not None:
                self.profiler = StepProfiler(ambient)
        if self.profiler is not None:
            self.profiler.bind(params, n_slots, max_seq)

    def submit(self, req: Request) -> bool:
        ok = self.batcher.submit(req.request_id, len(req.prompt),
                                 req.max_new_tokens,
                                 scenario=req.scenario)
        if ok:
            self._requests[req.request_id] = req
        return ok

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(p.shape[-1], p=pi)
                         for pi in p], np.int32)

    def _decode_once(self, cache, next_tok):
        """One jitted decode step, profiler-sampled when due."""
        prof = self.profiler
        if prof is not None and prof.due(self.steps_run):
            # Sampled step: time to a blocking boundary. Only these
            # steps pay the extra sync; the rest overlap as before.
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(next_tok))
            logits = jax.block_until_ready(logits)
            prof.on_step((time.perf_counter() - t0) * 1e6)
        else:
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(next_tok))
        self.steps_run += 1
        return logits, cache

    def _tick_services(self, m) -> None:
        """Per-decode-step collaborator ticks (both modes)."""
        if m is not None:
            m.counter("serve.decode_steps").inc()
        for svc in self.online:
            svc.tick()
        if self.sync is not None:
            fails_before = self.sync.failures
            pulled = self.sync.tick()
            if m is not None:
                if pulled is not None:
                    outcome = "pulled"
                elif self.sync.failures > fails_before:
                    outcome = "failed"
                else:
                    outcome = "skipped"
                m.counter("serve.sync_tick", outcome=outcome).inc()

    # -- cohort mode ---------------------------------------------------------

    def _run_cohort(self, members: list[tuple[int, int, int]]) -> None:
        """members: [(slot, request_id, prompt_len)]. Fresh cache; decode
        in lock-step until every member has its tokens."""
        cache = self.model.init_cache(self.n_slots, self.max_seq)
        reqs = {slot: self._requests[rid] for slot, rid, _ in members}
        done = {slot: False for slot in reqs}
        next_tok = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in reqs.items():
            next_tok[slot, 0] = req.prompt[0]
        t = 0
        while not all(done.values()) and t < self.max_seq - 1:
            m = obs.metrics()
            live = sum(1 for v in done.values() if not v)
            self._useful_slot_steps += live
            if m is not None:
                m.histogram("batch.occupancy",
                            UNIT_BUCKETS).observe(live / self.n_slots)
            logits, cache = self._decode_once(cache, next_tok)
            self._tick_services(m)
            sampled = self._sample(np.asarray(logits[:, 0]))
            for slot, req in reqs.items():
                if done[slot]:
                    continue
                if t + 1 < len(req.prompt):
                    next_tok[slot, 0] = req.prompt[t + 1]   # still feeding
                else:
                    req.tokens.append(int(sampled[slot]))
                    next_tok[slot, 0] = sampled[slot]
                    if len(req.tokens) >= req.max_new_tokens:
                        done[slot] = True
            t += 1
        # release slots
        for slot, rid, _ in members:
            s = self.batcher.slots[slot]
            self.batcher.finished.append(rid)
            s.active = False
            s.request_id = None
        m = obs.metrics()
        if m is not None:
            m.counter("serve.requests_completed").inc(len(members))

    def _run_cohort_mode(self, max_cohorts: int) -> int:
        cohorts = 0
        for _ in range(max_cohorts):
            if self.batcher.done():
                break
            members = self.batcher.admit()
            if not members:
                continue
            m = obs.metrics()
            if m is not None:
                m.histogram("serve.cohort_size",
                            COUNT_BUCKETS).observe(len(members))
                m.gauge("serve.queue_depth").set(self.batcher.queue_depth)
            tr = obs.tracer()
            if tr is not None:
                with tr.span("serve.cohort", cat="serve",
                             cohort=cohorts, size=len(members)):
                    self._run_cohort(members)
            else:
                self._run_cohort(members)
            cohorts += 1
        return cohorts

    # -- token mode ----------------------------------------------------------

    def _run_arena(self) -> None:
        """One arena generation: fresh cache, write cursor at 0, then
        token-level decode — freed slots admit queued requests mid-stream
        at the current cursor — until the queue and slots drain or the
        remaining arena cannot hold the next (head-of-line) request."""
        b = self.batcher
        cache = self.model.init_cache(self.n_slots, self.max_seq)
        starts = np.zeros(self.n_slots, np.int32)
        fed = [0] * self.n_slots           # prompt tokens fed per slot
        next_tok = np.zeros((self.n_slots, 1), np.int32)
        arena_pos = 0
        while arena_pos < self.max_seq:
            m = obs.metrics()
            active_before = b.active_slots
            admitted = b.admit(arena_pos=arena_pos)
            for slot, rid, _plen in admitted:
                req = self._requests[rid]
                next_tok[slot, 0] = req.prompt[0]
                starts[slot] = arena_pos
                fed[slot] = 1
            if admitted and active_before > 0:
                self._inflight_admissions += len(admitted)
            if admitted and m is not None:
                m.gauge("serve.queue_depth").set(b.queue_depth)
            active = [i for i, s in enumerate(b.slots) if s.active]
            if not active:
                break       # drained, or head request needs a fresh arena
            self._useful_slot_steps += len(active)
            if m is not None:
                m.histogram("batch.occupancy",
                            UNIT_BUCKETS).observe(len(active)
                                                  / self.n_slots)
            cache["start"] = jnp.asarray(starts)
            logits, cache = self._decode_once(cache, next_tok)
            arena_pos += 1
            self._tick_services(m)
            sampled = self._sample(np.asarray(logits[:, 0]))
            completed = 0
            for i in active:
                req = self._requests[b.slots[i].request_id]
                if fed[i] < len(req.prompt):
                    next_tok[i, 0] = req.prompt[fed[i]]     # still feeding
                    fed[i] += 1
                    continue
                req.tokens.append(int(sampled[i]))
                next_tok[i, 0] = sampled[i]
                if b.advance(i) is not None:
                    completed += 1          # slot freed; refilled next step
            if completed and m is not None:
                m.counter("serve.requests_completed").inc(completed)

    def _run_token_mode(self, max_generations: int) -> int:
        generations = 0
        while generations < max_generations and not self.batcher.done():
            tr = obs.tracer()
            if tr is not None:
                with tr.span("serve.arena", cat="serve",
                             generation=generations):
                    self._run_arena()
            else:
                self._run_arena()
            generations += 1
        return generations

    # -- driver --------------------------------------------------------------

    def run(self, max_cohorts: int = 1000) -> ServeReport:
        """Serve every submitted request to completion. ``max_cohorts``
        bounds lock-step cohorts (cohort mode) or arena generations
        (token mode) as a runaway backstop."""
        steps0 = self.steps_run
        done0 = len(self.batcher.finished)
        useful0 = self._useful_slot_steps
        inflight0 = self._inflight_admissions
        switches0 = self.batcher.scenario_switches
        pulls0 = self.sync.pulls if self.sync is not None else 0
        fails0 = self.sync.failures if self.sync is not None else 0
        if self.mode == "token":
            cohorts = self._run_token_mode(max_cohorts)
        else:
            cohorts = self._run_cohort_mode(max_cohorts)
        steps = self.steps_run - steps0
        useful = self._useful_slot_steps - useful0
        return ServeReport(
            outputs={rid: r.tokens for rid, r in self._requests.items()},
            cohorts=cohorts,
            requests_completed=len(self.batcher.finished) - done0,
            steps=steps,
            sync_pulls=(self.sync.pulls - pulls0
                        if self.sync is not None else 0),
            sync_failures=(self.sync.failures - fails0
                           if self.sync is not None else 0),
            mode=self.mode,
            occupancy=(round(useful / (steps * self.n_slots), 4)
                       if steps else 0.0),
            inflight_admissions=self._inflight_admissions - inflight0,
            scenario_switches=(self.batcher.scenario_switches
                               - switches0))
