"""Serving engine: batched decode over a slot arena, driven by the
continuous batcher in *cohort* mode.

The KV cache is a static (n_slots, max_seq) arena with a single write
cursor (``cache["pos"]``), so slots advance in lock-step: the batcher admits
a cohort of requests into free slots, the engine feeds each slot its own
prompt token-by-token (slots with shorter prompts start sampling earlier),
and the cohort runs until every member finishes; then the next cohort is
admitted. Per-slot write cursors (true token-level continuous batching)
would need scatter cache writes — noted in DESIGN.md as the production
extension; cohort mode is the standard static-arena TPU serving pattern.

Greedy (argmax) or temperature sampling.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import runtime as obs
from repro.obs.metrics import COUNT_BUCKETS

from .batching import ContinuousBatcher


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    tokens: list = field(default_factory=list)   # generated


@dataclass
class ServeReport:
    """What one :meth:`ServeEngine.run` call did.

    Mapping-compatible with the historical ``{request_id: tokens}``
    return value (``report[rid]``, iteration, ``len``, ``in`` all
    delegate to :attr:`outputs`), so existing callers keep working while
    new ones read the run stats directly.
    """

    outputs: dict[int, list[int]]
    cohorts: int = 0
    requests_completed: int = 0
    steps: int = 0
    sync_pulls: int = 0
    sync_failures: int = 0

    def __getitem__(self, request_id: int) -> list[int]:
        return self.outputs[request_id]

    def __iter__(self):
        return iter(self.outputs)

    def __len__(self) -> int:
        return len(self.outputs)

    def __contains__(self, request_id: int) -> bool:
        return request_id in self.outputs

    def keys(self):
        return self.outputs.keys()

    def values(self):
        return self.outputs.values()

    def items(self):
        return self.outputs.items()

    def to_json(self) -> dict:
        return {"cohorts": self.cohorts,
                "requests_completed": self.requests_completed,
                "steps": self.steps, "sync_pulls": self.sync_pulls,
                "sync_failures": self.sync_failures}


class ServeEngine:
    def __init__(self, model, params, n_slots: int = 4,
                 max_seq: int = 512, temperature: float = 0.0,
                 rng_seed: int = 0, online=None, sync=None,
                 profiler=None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.batcher = ContinuousBatcher(n_slots, max_seq)
        self._decode = jax.jit(model.decode_step)
        self._requests: dict[int, Request] = {}
        self._rng = np.random.default_rng(rng_seed)
        self.steps_run = 0
        # Optional online autotuner(s) (repro.online.OnlineTuner): each
        # decode step sponsors one launch-budget slice of background tuning
        # via tick(). Kernels launched inside the jitted decode report
        # their scenario at trace time (observe_traced); tick() screens
        # them and — under the cost-model objective — resolves their
        # bracket too, since live trials can't be interleaved into a
        # compiled graph. Promotions land in wisdom for the next trace.
        if online is None:
            online = []
        elif not isinstance(online, (list, tuple)):
            online = [online]
        self.online = list(online)
        # Optional fleet wisdom pull (repro.distrib.PullSync): tick() is
        # called once per decode step and actually pulls every
        # sync.interval ticks, merging fleet wisdom into the local store
        # and hot-refreshing attached kernels — this host serves with the
        # whole fleet's tuning results, not just its own.
        self.sync = sync
        # Optional decode-step profiler (repro.prof.StepProfiler): every
        # Nth step is timed to a blocking boundary and recorded as a
        # "serve.decode" roofline profile (params streamed from HBM per
        # step → small-batch decode is memory-bound; the profile says by
        # how much, and drifts against the run's first sampled step).
        # Unsampled steps pay one None check — no extra block/clock.
        self.profiler = profiler
        if profiler is None:
            from repro.prof.profiler import (StepProfiler,
                                             process_profiler)
            ambient = process_profiler()
            if ambient is not None:
                self.profiler = StepProfiler(ambient)
        if self.profiler is not None:
            self.profiler.bind(params, n_slots, max_seq)

    def submit(self, req: Request) -> bool:
        ok = self.batcher.submit(req.request_id, len(req.prompt),
                                 req.max_new_tokens)
        if ok:
            self._requests[req.request_id] = req
        return ok

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return logits.argmax(-1).astype(np.int32)
        z = logits / self.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(p.shape[-1], p=pi)
                         for pi in p], np.int32)

    def _run_cohort(self, members: list[tuple[int, int, int]]) -> None:
        """members: [(slot, request_id, prompt_len)]. Fresh cache; decode
        in lock-step until every member has its tokens."""
        cache = self.model.init_cache(self.n_slots, self.max_seq)
        reqs = {slot: self._requests[rid] for slot, rid, _ in members}
        done = {slot: False for slot in reqs}
        next_tok = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in reqs.items():
            next_tok[slot, 0] = req.prompt[0]
        t = 0
        while not all(done.values()) and t < self.max_seq - 1:
            prof = self.profiler
            if prof is not None and prof.due(self.steps_run):
                # Sampled step: time to a blocking boundary. Only these
                # steps pay the extra sync; the rest overlap as before.
                t0 = time.perf_counter()
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(next_tok))
                logits = jax.block_until_ready(logits)
                prof.on_step((time.perf_counter() - t0) * 1e6)
            else:
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(next_tok))
            self.steps_run += 1
            m = obs.metrics()
            if m is not None:
                m.counter("serve.decode_steps").inc()
            for svc in self.online:
                svc.tick()
            if self.sync is not None:
                fails_before = self.sync.failures
                pulled = self.sync.tick()
                if m is not None:
                    if pulled is not None:
                        outcome = "pulled"
                    elif self.sync.failures > fails_before:
                        outcome = "failed"
                    else:
                        outcome = "skipped"
                    m.counter("serve.sync_tick", outcome=outcome).inc()
            sampled = self._sample(np.asarray(logits[:, 0]))
            for slot, req in reqs.items():
                if done[slot]:
                    continue
                if t + 1 < len(req.prompt):
                    next_tok[slot, 0] = req.prompt[t + 1]   # still feeding
                else:
                    req.tokens.append(int(sampled[slot]))
                    next_tok[slot, 0] = sampled[slot]
                    if len(req.tokens) >= req.max_new_tokens:
                        done[slot] = True
            t += 1
        # release slots
        for slot, rid, _ in members:
            s = self.batcher.slots[slot]
            self.batcher.finished.append(rid)
            s.active = False
            s.request_id = None
        m = obs.metrics()
        if m is not None:
            m.counter("serve.requests_completed").inc(len(members))

    def run(self, max_cohorts: int = 1000) -> ServeReport:
        steps0 = self.steps_run
        done0 = len(self.batcher.finished)
        pulls0 = self.sync.pulls if self.sync is not None else 0
        fails0 = self.sync.failures if self.sync is not None else 0
        cohorts = 0
        for _ in range(max_cohorts):
            if self.batcher.done():
                break
            members = self.batcher.admit()
            if not members:
                continue
            m = obs.metrics()
            if m is not None:
                m.histogram("serve.cohort_size",
                            COUNT_BUCKETS).observe(len(members))
                m.gauge("serve.queue_depth").set(len(self.batcher.queue))
            tr = obs.tracer()
            if tr is not None:
                with tr.span("serve.cohort", cat="serve",
                             cohort=cohorts, size=len(members)):
                    self._run_cohort(members)
            else:
                self._run_cohort(members)
            cohorts += 1
        return ServeReport(
            outputs={rid: r.tokens for rid, r in self._requests.items()},
            cohorts=cohorts,
            requests_completed=len(self.batcher.finished) - done0,
            steps=self.steps_run - steps0,
            sync_pulls=(self.sync.pulls - pulls0
                        if self.sync is not None else 0),
            sync_failures=(self.sync.failures - fails0
                           if self.sync is not None else 0))
