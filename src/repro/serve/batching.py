"""Continuous batching scheduler with scenario-bucketed admission.

Fixed-slot batching (the KV cache is a static (B, S) arena under jit):
requests occupy slots; a finished request frees its slot immediately and a
queued request is admitted on the next step with a per-slot prefill.
Admission control rejects requests longer than the arena.

Queued requests are *bucketed by tuned scenario key* (the
``core/scenario.py`` ``format_key`` strings wisdom records are selected
by): admission drains one bucket FIFO before switching to the oldest
remaining bucket, so the slots running concurrently share a scenario and
each decode launch lands on a wisdom-exact config instead of forcing a
shape-miss fallback. Within a bucket, admission order is submission order
— never reordered, property-tested in ``tests/test_serve_batching.py``.

Token-level callers (``ServeEngine`` in token mode) pass their arena
write cursor to :meth:`ContinuousBatcher.admit`: a request that no longer
fits the remaining arena blocks admission head-of-line (no skipping —
that would starve long requests) until the engine opens a fresh arena
generation. Pure bookkeeping, unit-tested without a model.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass


@dataclass
class Slot:
    request_id: int | None = None
    pos: int = 0                  # tokens generated so far (incl. prompt)
    max_pos: int = 0              # stop position
    active: bool = False
    scenario: str = ""            # bucket the request was admitted from
    start: int = 0                # arena write cursor at admission


@dataclass
class QueuedRequest:
    """One queued submission: identity, lengths, its scenario bucket, and
    a global arrival sequence number (the FIFO evidence — ``queue`` sorts
    on it, and the stress tests assert per-bucket admission follows it)."""
    request_id: int
    prompt_len: int
    max_new_tokens: int
    scenario: str
    seq: int


class ContinuousBatcher:
    """Slot scheduler for continuous batching (see module docstring).

    Bookkeeping only — owns no model or cache. ``submit`` enqueues (or
    rejects oversize), ``admit`` fills free slots from the scenario
    buckets, ``step``/``advance`` move slots forward and free finished
    ones. ``finished``/``rejected`` are append-only audit logs."""

    def __init__(self, n_slots: int, max_seq: int):
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.slots = [Slot() for _ in range(n_slots)]
        self.finished: list[int] = []
        self.rejected: list[int] = []
        # scenario key -> FIFO of queued requests. A dict preserves
        # insertion order; _next() picks by oldest head, not dict order.
        self.buckets: dict[str, deque[QueuedRequest]] = {}
        #: Bucket admissions are currently drawing from (sticky until it
        #: empties, so slots keep sharing a scenario).
        self.active_scenario: str | None = None
        #: Times admission moved to a different bucket (telemetry: each
        #: switch is a likely config/compile change for the next launch).
        self.scenario_switches = 0
        self._arrivals = 0

    # -- intake --------------------------------------------------------------

    def submit(self, request_id: int, prompt_len: int,
               max_new_tokens: int, scenario: str = "") -> bool:
        """Enqueue a request into its scenario bucket; False (and logged
        in ``rejected``) if it cannot ever fit the arena."""
        if prompt_len + max_new_tokens > self.max_seq:
            self.rejected.append(request_id)
            return False
        bucket = self.buckets.setdefault(str(scenario), deque())
        bucket.append(QueuedRequest(request_id, prompt_len, max_new_tokens,
                                    str(scenario), self._arrivals))
        self._arrivals += 1
        return True

    # -- admission -----------------------------------------------------------

    def _oldest_bucket(self) -> str | None:
        live = [(q[0].seq, name) for name, q in self.buckets.items() if q]
        if not live:
            return None
        return min(live)[1]

    def _next(self, arena_pos: int) -> QueuedRequest | None:
        """Pop the next admissible request: stay on the active bucket
        until it drains, then switch to the bucket whose head arrived
        first. Head-of-line within the bucket: if the head does not fit
        the remaining arena, nothing is admitted (no skipping)."""
        name = self.active_scenario
        if name is None or not self.buckets.get(name):
            name = self._oldest_bucket()
            if name is None:
                return None
            if self.active_scenario is not None \
                    and name != self.active_scenario:
                self.scenario_switches += 1
            self.active_scenario = name
        head = self.buckets[name][0]
        if arena_pos + head.prompt_len + head.max_new_tokens > self.max_seq:
            return None
        return self.buckets[name].popleft()

    def admit(self, arena_pos: int = 0) -> list[tuple[int, int, int]]:
        """Fill free slots from the scenario buckets.

        ``arena_pos`` is the caller's arena write cursor (token-level
        engines); a request needing more arena than remains blocks
        head-of-line. Cohort callers leave it 0 (whole arena free).
        Returns [(slot_idx, request_id, prompt_len)] needing prefill."""
        admitted = []
        for i, s in enumerate(self.slots):
            if s.active:
                continue
            nxt = self._next(arena_pos)
            if nxt is None:
                break
            self.slots[i] = Slot(request_id=nxt.request_id,
                                 pos=nxt.prompt_len,
                                 max_pos=nxt.prompt_len + nxt.max_new_tokens,
                                 active=True, scenario=nxt.scenario,
                                 start=arena_pos)
            admitted.append((i, nxt.request_id, nxt.prompt_len))
        return admitted

    # -- progress ------------------------------------------------------------

    def advance(self, slot_idx: int) -> int | None:
        """Advance one slot by one token; frees the slot and returns the
        request id when it finishes (else None). Token-level engines call
        this per slot per generated token — slots still being prefilled
        are simply not advanced that step."""
        s = self.slots[slot_idx]
        if not s.active:
            return None
        s.pos += 1
        if s.pos >= s.max_pos:
            rid = s.request_id
            self.finished.append(rid)
            s.active = False
            s.request_id = None
            return rid
        return None

    def step(self) -> list[int]:
        """Advance every active slot one token (lock-step/cohort view);
        returns freed request ids."""
        freed = []
        for i, s in enumerate(self.slots):
            if s.active:
                rid = self.advance(i)
                if rid is not None:
                    freed.append(rid)
        return freed

    # -- introspection -------------------------------------------------------

    @property
    def queue(self) -> list[QueuedRequest]:
        """All queued requests in global arrival order (flattened view
        over the scenario buckets; read-only snapshot)."""
        out = [r for bucket in self.buckets.values() for r in bucket]
        out.sort(key=lambda r: r.seq)
        return out

    @property
    def queue_depth(self) -> int:
        return sum(len(bucket) for bucket in self.buckets.values())

    @property
    def active_slots(self) -> int:
        return sum(1 for s in self.slots if s.active)

    def done(self) -> bool:
        return self.queue_depth == 0 and self.active_slots == 0
