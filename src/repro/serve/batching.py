"""Continuous batching scheduler.

Fixed-slot batching (the KV cache is a static (B, S) arena under jit):
requests occupy slots; finished requests free their slot immediately and a
queued request is admitted on the next step with a per-slot prefill.
Admission control rejects requests longer than the arena. Pure bookkeeping,
unit-tested without a model.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class Slot:
    request_id: int | None = None
    pos: int = 0                  # tokens generated so far (incl. prompt)
    max_pos: int = 0              # stop position
    active: bool = False


@dataclass
class ContinuousBatcher:
    n_slots: int
    max_seq: int
    queue: deque = field(default_factory=deque)
    slots: list[Slot] = field(default_factory=list)
    finished: list[int] = field(default_factory=list)
    rejected: list[int] = field(default_factory=list)

    def __post_init__(self):
        if not self.slots:
            self.slots = [Slot() for _ in range(self.n_slots)]

    def submit(self, request_id: int, prompt_len: int,
               max_new_tokens: int) -> bool:
        if prompt_len + max_new_tokens > self.max_seq:
            self.rejected.append(request_id)
            return False
        self.queue.append((request_id, prompt_len, max_new_tokens))
        return True

    def admit(self) -> list[tuple[int, int, int]]:
        """Fill free slots from the queue.
        Returns [(slot_idx, request_id, prompt_len)] needing prefill."""
        admitted = []
        for i, s in enumerate(self.slots):
            if s.active or not self.queue:
                continue
            rid, plen, mnew = self.queue.popleft()
            self.slots[i] = Slot(request_id=rid, pos=plen,
                                 max_pos=plen + mnew, active=True)
            admitted.append((i, rid, plen))
        return admitted

    def step(self) -> list[int]:
        """Advance every active slot one token; returns freed request ids."""
        freed = []
        for s in self.slots:
            if not s.active:
                continue
            s.pos += 1
            if s.pos >= s.max_pos:
                freed.append(s.request_id)
                self.finished.append(s.request_id)
                s.active = False
                s.request_id = None
        return freed

    @property
    def active_slots(self) -> int:
        return sum(1 for s in self.slots if s.active)

    def done(self) -> bool:
        return not self.queue and self.active_slots == 0
