from .engine import ServeEngine, ServeReport, Request
from .batching import ContinuousBatcher

__all__ = ["ServeEngine", "ServeReport", "Request", "ContinuousBatcher"]
