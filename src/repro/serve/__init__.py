from .engine import ServeEngine, Request
from .batching import ContinuousBatcher

__all__ = ["ServeEngine", "Request", "ContinuousBatcher"]
