"""``repro.wisdom`` — the operator entry point for wisdom stores.

``python -m repro.wisdom <subcommand>`` manages the wisdom directories the
runtime reads (§4.4) and the fleet distribution layer syncs
(``repro.distrib``). The implementation lives in ``repro.distrib.cli``;
this package only provides the memorable module path.
"""

from repro.distrib.cli import build_parser, main

__all__ = ["build_parser", "main"]
